"""Radix-tree prefix cache over paged mixer state (DESIGN.md §11).

Requests sharing a system prompt should prefill it once.  The classic
KV-cache trick — match the prompt against a radix tree of previously
prefilled token runs and fork the matched blocks copy-on-write — assumes
the *entire* per-token state lives in pageable KV.  Here it doesn't:
hyena carries a rolling short-conv window and a cursor, recurrent mixers
carry O(1) states, local attention carries a ring.  Those leaves are
pinned (dense per-slot), so each radix node additionally stores a
*pinned-state snapshot*: the batch-1 slice of every pinned cache leaf as
it stood immediately after absorbing that node's page.  Forking a prefix
therefore restores BOTH the paged blocks (by reference, COW) and the
pinned rows (by copy), which is what makes prefix reuse correct for
every decode-capable mixer rather than just attention.

Tree shape: one node per *page* (``page_size`` tokens), keyed by the
page's token tuple.  Nodes are only created at exact page boundaries —
the engine clips prompt-feed quanta to page boundaries so the snapshot
it hands us is exactly the state after ``depth * page_size`` tokens.
Matching is whole-page and capped so at least one prompt token is left
to feed (the model needs an input token to produce the first logits).

Block references: each node holds one block id with a refcount taken on
the shared :class:`~repro.serve.paged.BlockAllocator`; forks take their
own ref.  LRU eviction (under allocator pressure, or random eviction in
the parity harness) drops leaf nodes only, decrefs their block, and
returns any block that hit refcount zero so the engine can zero it
(invariant I3 of DESIGN.md §4 extends to physical blocks).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple


class RadixNode:
    __slots__ = ("tokens", "block", "snapshot", "children", "parent", "last_used")

    def __init__(self, tokens: Tuple[int, ...], block: int,
                 snapshot: Optional[List[Any]], parent: "RadixNode | None"):
        self.tokens = tokens
        self.block = block
        self.snapshot = snapshot  # pinned leaves (batch-1) after this page
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_used = 0


class RadixPrefixCache:
    def __init__(self, page_size: int, allocator) -> None:
        self.page = int(page_size)
        self.alloc = allocator
        self.root = RadixNode((), -1, None, None)
        self._tick = 0
        self.n_nodes = 0
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------- match
    def match(self, tokens: Sequence[int]) -> Tuple[int, List[int], Optional[List[Any]]]:
        """Longest whole-page prefix of ``tokens`` present in the tree.

        Returns ``(n_matched_tokens, block_ids, snapshot)`` where
        ``snapshot`` is the deepest matched node's pinned state.  The match
        is capped at ``len(tokens) - 1`` so the caller always has a token
        to feed.  Does NOT take block references — the caller increfs the
        returned blocks if it commits to the fork.
        """
        limit = len(tokens) - 1
        node, depth, blocks = self.root, 0, []
        self._tick += 1
        while depth + self.page <= limit:
            key = tuple(int(t) for t in tokens[depth:depth + self.page])
            child = node.children.get(key)
            if child is None or child.snapshot is None:
                break
            child.last_used = self._tick
            blocks.append(child.block)
            node, depth = child, depth + self.page
        if depth:
            self.hits += 1
        else:
            self.misses += 1
        snap = node.snapshot if depth else None
        return depth, blocks, snap

    # ------------------------------------------------------------ insert
    def insert(self, tokens: Sequence[int], blocks: Sequence[int],
               snapshot: List[Any]) -> bool:
        """Record a fully-paged prefix: ``tokens`` (length = k * page) with
        its backing ``blocks`` (one per page) and the pinned-state snapshot
        taken after the final token.  Only the deepest node is (possibly)
        new — the engine inserts at every page boundary as prefill
        advances, so ancestors exist already; if one is missing (its chain
        was LRU-evicted since the donor prefilled), it is re-created
        without a snapshot and is unusable for forks until re-inserted.
        Returns True if a new reference was taken for the deepest node.
        """
        n = len(tokens)
        if n == 0 or n % self.page != 0 or n // self.page != len(blocks):
            raise ValueError("insert requires a page-aligned prefix with one block per page")
        self._tick += 1
        node = self.root
        for i, blk in enumerate(blocks):
            key = tuple(int(t) for t in tokens[i * self.page:(i + 1) * self.page])
            child = node.children.get(key)
            last = i == len(blocks) - 1
            if child is None:
                child = RadixNode(key, int(blk), snapshot if last else None, node)
                node.children[key] = child
                self.alloc.incref(int(blk))
                self.n_nodes += 1
                child.last_used = self._tick
                node = child
                if last:
                    return True
            else:
                child.last_used = self._tick
                if last and child.snapshot is None:
                    child.snapshot = snapshot
                node = child
        return False

    # ----------------------------------------------------------- evict
    def _leaves(self) -> List[RadixNode]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                if c.children:
                    stack.append(c)
                else:
                    out.append(c)
        return out

    def _drop(self, leaf: RadixNode) -> Optional[int]:
        key = leaf.tokens
        assert leaf.parent is not None and not leaf.children
        del leaf.parent.children[key]
        self.n_nodes -= 1
        freed = self.alloc.decref(leaf.block)
        return leaf.block if freed else None

    def evict_lru(self, n_blocks: int = 1) -> List[int]:
        """Drop up to ``n_blocks`` least-recently-used leaf nodes; returns
        block ids whose refcount reached zero (caller must zero them)."""
        zeroed: List[int] = []
        for _ in range(n_blocks):
            leaves = self._leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            blk = self._drop(victim)
            if blk is not None:
                zeroed.append(blk)
        return zeroed

    def evict_node(self, rng) -> List[int]:
        """Drop one uniformly-random leaf (parity-harness chaos hook)."""
        leaves = self._leaves()
        if not leaves:
            return []
        victim = leaves[int(rng.integers(0, len(leaves)))]
        blk = self._drop(victim)
        return [blk] if blk is not None else []

    def flush(self) -> List[int]:
        """Drop every node; returns all blocks that hit refcount zero."""
        zeroed: List[int] = []
        while self.n_nodes:
            zeroed.extend(self.evict_lru(self.n_nodes))
        return zeroed
