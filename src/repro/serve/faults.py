"""Deterministic fault injection for the serve engines (DESIGN.md §13).

The chaos harness (tests/serve_parity.py) proves the serve fault contract
— every admitted request either completes token-identical to the fault-
free reference or terminates with a structured ``RequestResult`` status —
by *injecting* the failure modes the contract covers:

  * **NaN/Inf logits** into chosen slots of the decode quantum (and the
    dense engine's admission prefill), exercising the NaN quarantine:
    per-slot finite guard -> quarantine -> deterministic replay -> N-strike
    structured failure.
  * **Transient step/prefill errors** (:class:`TransientStepError`),
    raised at the host boundary *before* the jitted call dispatches (so
    donated pool buffers are never consumed by a failed step), exercising
    the bounded retry-with-backoff path.
  * **Allocator exhaustion** in the paged engine's block-allocation path,
    exercising the stall-and-retry quantum (adv = 0).
  * **Slow steps** (injected sleeps), exercising the straggler/stuck-step
    detection surfaced by ``engine.health()``.

Every decision is a pure function of ``(seed, kind, *key)`` — the same
schedule-keyed determinism as the engines' ``(seed, rid, token_index)``
sampling streams — so a failing chaos seed replays exactly.  Logit poison
keys additionally include the request's quarantine *attempt*: a replayed
request draws fresh coins, which is what lets a transiently poisoned
request complete token-identical after replay, while ``poison_attempts``
(or rate draws that keep firing) exercises the strike-out path.

Off by default: engines built without an injector skip every hook, and the
always-on finite guard is the only addition to the jitted decode program
(one ``isfinite`` reduce over the per-slot logits).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np


class TransientStepError(RuntimeError):
    """A transient, retryable failure in a serve step or prefill (the
    injected stand-in for device hiccups / collective timeouts).  Raised
    before the jitted call dispatches, so engine state is never torn."""


_KINDS = ("nan", "inf", "step", "prefill", "alloc", "slow")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, deterministic fault schedule.  All rates are per-decision
    probabilities in [0, 1]; explicit schedules compose with the rates."""

    seed: int = 0
    # --- logit poisoning (per (rid, token_index, attempt) emission)
    nan_logit_rate: float = 0.0
    inf_logit_rate: float = 0.0
    # explicit targets: (rid, token_index, "nan"|"inf") — fired on
    # attempts < poison_attempts, so poison_attempts=1 tests clean replay
    # and a large value tests the N-strike structured failure
    poison_tokens: Tuple[Tuple[int, int, str], ...] = ()
    poison_attempts: int = 1
    # --- transient failures (per (tick, attempt) / (tick, rid, attempt))
    step_error_rate: float = 0.0
    prefill_error_rate: float = 0.0
    # --- paged allocator exhaustion (per (tick, slot))
    alloc_fail_rate: float = 0.0
    # --- slow steps (per tick)
    slow_step_rate: float = 0.0
    slow_step_seconds: float = 0.0

    def __post_init__(self):
        for f in ("nan_logit_rate", "inf_logit_rate", "step_error_rate",
                  "prefill_error_rate", "alloc_fail_rate", "slow_step_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        for rid, tix, kind in self.poison_tokens:
            if kind not in ("nan", "inf"):
                raise ValueError(f"poison kind must be nan|inf, got {kind!r}")
            if rid < 0 or tix < 0:
                raise ValueError("poison_tokens entries must be >= 0")

    @property
    def poisons(self) -> bool:
        return bool(self.nan_logit_rate or self.inf_logit_rate
                    or self.poison_tokens)


class FaultInjector:
    """Evaluates a :class:`FaultPlan` deterministically and counts what it
    fired (the counters feed the chaos harness's assertions)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: Dict[str, int] = {k: 0 for k in _KINDS}
        self._targets = {
            (int(rid), int(tix)): kind
            for rid, tix, kind in plan.poison_tokens
        }

    # ------------------------------------------------------ deterministic
    def _coin(self, kind: str, *key: int) -> float:
        """Uniform [0, 1) draw, a pure function of (seed, kind, key)."""
        seq = (self.plan.seed, _KINDS.index(kind)) + tuple(
            int(k) for k in key
        )
        return float(np.random.default_rng(seq).random())

    # ------------------------------------------------------------- logits
    def poison_value(self, rid: int, token_index: int,
                     attempt: int) -> float:
        """0.0 (clean), NaN, or +Inf to add to the slot's logits row for
        the emission at ``token_index``.  ``attempt`` is the request's
        quarantine count: replays draw fresh coins, and explicit targets
        stop firing once ``attempt >= poison_attempts``."""
        kind = self._targets.get((int(rid), int(token_index)))
        if kind is not None and attempt < self.plan.poison_attempts:
            self.fired[kind] += 1
            return math.nan if kind == "nan" else math.inf
        p = self.plan
        if p.nan_logit_rate and self._coin(
                "nan", rid, token_index, attempt) < p.nan_logit_rate:
            self.fired["nan"] += 1
            return math.nan
        if p.inf_logit_rate and self._coin(
                "inf", rid, token_index, attempt) < p.inf_logit_rate:
            self.fired["inf"] += 1
            return math.inf
        return 0.0

    @property
    def poisons(self) -> bool:
        return self.plan.poisons

    # --------------------------------------------------------- transients
    def check_step(self, tick: int, attempt: int) -> None:
        """Raise :class:`TransientStepError` for this (tick, attempt) per
        ``step_error_rate`` — called before the decode quantum dispatches,
        once per retry attempt, so bounded retries can succeed."""
        p = self.plan
        if p.step_error_rate and self._coin(
                "step", tick, attempt) < p.step_error_rate:
            self.fired["step"] += 1
            raise TransientStepError(
                f"injected transient step error (tick {tick}, "
                f"attempt {attempt})"
            )

    def check_prefill(self, tick: int, rid: int, attempt: int) -> None:
        p = self.plan
        if p.prefill_error_rate and self._coin(
                "prefill", tick, rid, attempt) < p.prefill_error_rate:
            self.fired["prefill"] += 1
            raise TransientStepError(
                f"injected transient prefill error (tick {tick}, "
                f"rid {rid}, attempt {attempt})"
            )

    # ---------------------------------------------------------- allocator
    def alloc_fails(self, tick: int, slot: int) -> bool:
        """Transient allocator exhaustion for (tick, slot): the paged
        engine stalls the slot this quantum and retries next tick."""
        p = self.plan
        if p.alloc_fail_rate and self._coin(
                "alloc", tick, slot) < p.alloc_fail_rate:
            self.fired["alloc"] += 1
            return True
        return False

    # -------------------------------------------------------- slow steps
    def slow_step_seconds(self, tick: int) -> float:
        """Seconds this tick should stall (0.0 = no fault) — feeds the
        straggler monitor behind ``engine.health()``."""
        p = self.plan
        if p.slow_step_rate and p.slow_step_seconds and self._coin(
                "slow", tick) < p.slow_step_rate:
            self.fired["slow"] += 1
            return float(p.slow_step_seconds)
        return 0.0
