"""Continuous-batching scheduler: admission queue + fixed slot pool
(DESIGN.md §4).

The scheduler owns *request bookkeeping only* — which request sits in which
slot, what it has emitted, when it stops — and drives a model-agnostic
:class:`Backend` through one step loop:

    step():  admit queued requests into free slots (one prefill each,
             scattered into the pool), then run a SINGLE jitted decode step
             over the whole pool and dispatch each active slot's new token.

Invariants (asserted by the randomized-schedule property harness):

  I1  a slot is owned by at most one request at a time; admission order is
      FIFO over the queue, except that evicted requests readmit AHEAD of
      queued arrivals (starvation-freedom under sustained load).
  I2  per-request outputs are schedule-independent: whatever the arrival /
      eviction interleaving, a greedy request r emits exactly the tokens
      the sequential ``generate()`` of r would (token-identical serving);
      sampled requests are a deterministic function of (seed, rid,
      token index), never of slot placement or pool composition.
  I3  a released slot's per-slot state is reset to zeros before reuse — an
      evicted request's cache cannot leak into its successor.

Eviction is preemption-with-continuation: the slot is reset and the request
re-enters the queue with ``prompt + emitted`` as its new prompt, so a
readmission prefill reconstructs exactly the state the uninterrupted decode
would have had (the prefill/decode-parity contract every registered
TokenMixer is conformance-tested on).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls (one slot = one request = one set)."""

    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    stop_tokens: Tuple[int, ...] = ()


@dataclasses.dataclass
class Request:
    """One serving request plus its mutable schedule state."""

    rid: int
    prompt: np.ndarray  # (L,) int32 original prompt
    params: SamplingParams
    stream: Optional[Callable[[int, int, bool], None]] = None  # (rid, tok, done)
    # tick deadline for the WHOLE request (DESIGN.md §13): if it hasn't
    # finished by this scheduler tick it aborts with status
    # "deadline_exceeded" and partial tokens.  None = no deadline.
    deadline: Optional[int] = None
    # --- schedule state
    tokens: List[int] = dataclasses.field(default_factory=list)  # emitted
    slot: int = -1  # -1 = not resident
    evictions: int = 0
    quarantines: int = 0  # NaN-quarantine strikes (replays) so far

    @property
    def n_emitted(self) -> int:
        return len(self.tokens)

    @property
    def resume_prompt(self) -> np.ndarray:
        """Prompt for (re)admission: original prompt + everything emitted."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens, np.int32)]
        )

    def finished(self, token: int) -> bool:
        return (
            token in self.params.stop_tokens
            or self.n_emitted >= self.params.max_new_tokens
        )


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Terminal outcome of one request (the serve fault contract,
    DESIGN.md §13).  ``tokens`` always carries whatever the request
    emitted before the terminal event — partial output on aborts."""

    rid: int
    status: str  # completed|failed|deadline_exceeded|cancelled|shed
    tokens: Tuple[int, ...]
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.status == "completed"


TERMINAL_STATUSES = (
    "completed", "failed", "deadline_exceeded", "cancelled", "shed",
)


class Backend:
    """What the scheduler needs from the model side (implemented by
    :class:`repro.serve.engine.ServeEngine`)."""

    def prefill_into_slot(self, slot: int, req: Request) -> Optional[int]:
        """Prefill ``req.resume_prompt``, scatter the cache into ``slot``,
        and return the first sampled token — or None if the backend failed
        the admission structurally (e.g. NaN-quarantine strike-out during
        prefill); the scheduler then releases the slot and the backend
        owns finalizing the request."""
        raise NotImplementedError

    def decode_active(self, requests: Dict[int, Request]) -> Dict[int, list]:
        """One jitted decode *quantum* (>= 1 fused steps) over the pool;
        returns slot -> [tokens] for every active slot.  Tokens past a
        request's stop condition are surplus and will be discarded."""
        raise NotImplementedError

    def reset_slot(self, slot: int) -> None:
        """Zero a slot's per-slot cache state (pure-function reset)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Event:
    """One emitted token (streamed to the caller in step order)."""

    rid: int
    token: int
    done: bool


class Scheduler:
    """Admission queue + fixed slot pool + the continuous step loop."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.queue: deque[Request] = deque()
        # evicted requests re-enter HERE, drained before the arrival queue:
        # under sustained arrivals a FIFO requeue starves preempted requests
        # indefinitely (each readmission attempt lines up behind every
        # arrival that landed during its residency)
        self.readmit: deque[Request] = deque()
        self.slots: Dict[int, Request] = {}  # slot -> resident request
        self._free: List[int] = list(range(n_slots))[::-1]  # pop() -> slot 0 first

    # ------------------------------------------------------------- queries
    @property
    def active(self) -> Dict[int, Request]:
        return dict(self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.readmit and not self.slots

    # ------------------------------------------------------------ mutation
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def evict(self, rid: int, backend: Backend) -> bool:
        """Preempt a resident request: reset its slot and requeue it with
        ``prompt + emitted`` as the continuation prompt.  Returns False if
        ``rid`` is not resident (queued / finished requests are no-ops)."""
        for slot, req in list(self.slots.items()):
            if req.rid == rid:
                self._release(slot, backend)
                req.slot = -1
                req.evictions += 1
                self.readmit.append(req)  # ahead of every queued arrival
                return True
        return False

    def _release(self, slot: int, backend: Backend) -> None:
        backend.reset_slot(slot)
        del self.slots[slot]
        self._free.append(slot)

    def _emit(
        self, req: Request, token: int, backend: Backend,
        events: List[Event],
    ) -> None:
        req.tokens.append(int(token))
        done = req.finished(int(token))
        if done:
            self._release(req.slot, backend)
            req.slot = -1
        events.append(Event(req.rid, int(token), done))

    def _dispatch_streams(self, events: List[Event], by_rid) -> None:
        """Fire stream callbacks AFTER all bookkeeping for the tick: a
        raising callback leaves every request's tokens/slots/caches
        consistent (the exception propagates to the step() caller, who can
        still recover full outputs via drain()/results())."""
        for ev in events:
            req = by_rid.get(ev.rid)
            if req is not None and req.stream is not None:
                req.stream(ev.rid, ev.token, ev.done)

    # ----------------------------------------------------------- step loop
    def step(self, backend: Backend) -> List[Event]:
        """One scheduler tick: fill free slots from the queue (one prefill
        per admission), then a single jitted decode step over the pool."""
        events: List[Event] = []
        by_rid: Dict[int, Request] = {}
        # 1. admission: prefill-into-free-slots — readmitted (previously
        # evicted) requests first, then FIFO over new arrivals
        while (self.readmit or self.queue) and self._free:
            req = (self.readmit.popleft() if self.readmit
                   else self.queue.popleft())
            slot = self._free.pop()
            self.slots[slot] = req
            req.slot = slot
            by_rid[req.rid] = req
            first = backend.prefill_into_slot(slot, req)
            if first is None:
                # structural admission failure (e.g. prefill NaN-quarantine
                # strike-out): free the slot; the backend finalizes the
                # request with its structured RequestResult
                self._release(slot, backend)
                req.slot = -1
                continue
            self._emit(req, first, backend, events)
        # 2. one decode quantum over every active slot; a request that hits
        # its stop condition mid-quantum keeps tokens up to (and including)
        # the stop and discards the surplus — outputs are identical for
        # every quantum size
        if self.slots:
            snapshot = dict(self.slots)
            produced = backend.decode_active(snapshot)
            for slot, tokens in sorted(produced.items()):
                req = snapshot[slot]
                by_rid[req.rid] = req
                for token in tokens:
                    self._emit(req, token, backend, events)
                    if req.slot == -1:  # finished (slot already released)
                        break
        self._dispatch_streams(events, by_rid)
        return events
