"""Serving engines over the per-mixer caches (KV ring buffers for
attention, O(L) conv cache for Hyena, O(1) recurrent state for SSD /
RG-LRU).

Serving is **mesh-native** (DESIGN.md §9): construct the engine with an
``ExecutionContext`` carrying a mesh and the slot pool lives sharded by the
rule engine (model-axis heads/channels from each mixer's
``cache_shard_axes`` spec, replicated cursors), the decode quantum runs
tensor-parallel with donated sharded buffers, prefill routes long prompts
through the sequence-parallel ``fft_sp`` conv, and sampling replicates the
small ``(S, V)`` logits once per step to handle the vocab-sharded LM head.
Without a mesh every path degrades to the single-device program — the
token streams are identical either way (property-tested on a 2×4 debug
mesh in tests/test_serve_distributed.py).

Two tiers (DESIGN.md §4):

  * :func:`generate` — the static-batch path: every request in the batch
    shares one prompt length and one decode horizon.  Kept as the
    sequential *reference semantics* (the property harness asserts the
    continuous engine's greedy outputs are token-identical to it) and as
    the baseline ``benchmarks/bench_serving.py`` measures against.
  * :class:`ServeEngine` — continuous batching: an admission queue feeds a
    fixed pool of cache *slots*; each step interleaves prefill-into-free-
    slots with a single jitted decode step over the whole pool.  Requests
    carry their own sampling params (temperature / top_k / stop tokens),
    horizons, and streaming callbacks; slots are scattered/gathered through
    the TokenMixer cache-slot contract (``cache_slot_axes`` et al.).

Prefill runs the gated long-conv entry point (DESIGN.md §7: the Hyena
gate is fused inside the conv backend, no standalone full-tensor multiply)
and each Hyena decode step evaluates all orders' cache histories in one
stacked dot_general; conv tile plans come from ``repro.core.autotune``
(``$REPRO_AUTOTUNE`` — use ``load`` in serving, never ``search``).

Hyena's O(L) conv cache and the SSD/RG-LRU O(1) recurrent state make the
per-slot swap far cheaper than attention KV paging: inserting a slot moves
one operand history (or a single state vector), never a paged KV table.

``serve_step`` — one new token against a populated cache — is exactly what
the multi-pod dry-run lowers for the ``decode_32k`` / ``long_500k`` cells.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.ft import Heartbeat, StragglerMonitor, retry
from repro.common.policy import Policy
from repro.configs.base import ModelConfig
from repro.distributed.execution import ExecutionContext
from repro.models import lm
from repro.models.mixer_api import get_mixer
from repro.serve.faults import FaultInjector, TransientStepError
from repro.serve.sampling import sample, sample_slots
from repro.serve.scheduler import (
    Backend, Request, RequestResult, SamplingParams, Scheduler,
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    temperature: float = 0.0  # default for requests that don't override
    top_k: int = 0
    n_slots: int = 4  # continuous-batching slot-pool width
    # decode steps fused into one jitted lax.scan per scheduler tick:
    # amortizes per-token host dispatch; slots are admitted/released only at
    # quantum boundaries (a request finishing mid-quantum has its surplus
    # tokens discarded, so outputs stay token-identical to quantum=1)
    decode_quantum: int = 1
    cache_dtype: Any = jnp.bfloat16
    # hyena long-conv backend for the *prefill* pass (decode steps are
    # cached dots — no long conv to select)
    conv_backend: Optional[str] = None
    # mixed precision: None derives Policy(compute_dtype=cache_dtype) —
    # serving holds policy-cast weights (cast once at engine construction)
    policy: Optional[Policy] = None
    # --- failure-domain knobs (DESIGN.md §13)
    # NaN quarantine: a request whose logits go non-finite is evicted and
    # replayed from its last good token; after this many strikes it fails
    # structurally (status="failed") instead of replaying again
    quarantine_strikes: int = 2
    # bounded retry-with-backoff for transient step/prefill failures
    step_retry_attempts: int = 3
    step_retry_base_delay: float = 0.0  # 0 = retry immediately (tests)
    # load shedding: once queued work (queue + readmits) exceeds this, the
    # weakest queued arrival is rejected with status="shed"; 0 disables
    overload_threshold: int = 0
    # liveness file, atomically rewritten once per step() when set — an
    # external watchdog detects a hung engine by mtime
    heartbeat_path: Optional[str] = None

    def __post_init__(self):
        self.apply_context()  # unknown backend names fail here, not on the
        # first generate() of a deployed server
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.decode_quantum < 1:
            raise ValueError(
                f"decode_quantum must be >= 1, got {self.decode_quantum}"
            )
        if self.quarantine_strikes < 1:
            raise ValueError(
                f"quarantine_strikes must be >= 1, got "
                f"{self.quarantine_strikes}"
            )
        if self.step_retry_attempts < 1:
            raise ValueError(
                f"step_retry_attempts must be >= 1, got "
                f"{self.step_retry_attempts}"
            )
        if self.overload_threshold < 0:
            raise ValueError(
                f"overload_threshold must be >= 0, got "
                f"{self.overload_threshold}"
            )

    def apply_context(self, mesh=None) -> ExecutionContext:
        """Serving's single resolution point for execution options — the
        same ExecutionContext substrate training runs on (DESIGN.md §9).
        Pass a mesh to serve tensor-parallel."""
        return ExecutionContext(
            conv_backend=self.conv_backend,
            mesh=mesh,
            policy=self.policy or Policy(compute_dtype=self.cache_dtype),
        )


class DrainExhausted(RuntimeError):
    """``drain(max_steps)`` ran out of budget with requests still active.

    Carries everything the caller needs to recover or report instead of
    losing the work: ``partial`` is the full rid -> tokens map (finished
    plus in-flight prefixes, same shape as ``results()``) and ``active``
    the rids that were still queued or resident when the budget ran out.
    The engine is left consistent — stepping or draining again resumes
    exactly where the budget cut off."""

    def __init__(self, max_steps: int, partial, active):
        super().__init__(
            f"drain exceeded {max_steps} steps with {len(active)} "
            f"request(s) still active: {list(active)}"
        )
        self.max_steps = max_steps
        self.partial = partial
        self.active = tuple(active)


def resolve_serve_context(
    scfg: ServeConfig, ectx: Optional[ExecutionContext]
) -> ExecutionContext:
    """Merge ServeConfig execution options into an externally built
    context wherever the context doesn't set its own: every engine must
    honor the same policy/backend as the meshless engine and the
    ``generate()`` reference, or mesh-vs-meshless (and paged-vs-dense)
    token identity breaks for any non-default ServeConfig."""
    ctx = ectx if ectx is not None else scfg.apply_context()
    if ctx.policy is None:
        ctx = dataclasses.replace(
            ctx, policy=scfg.policy or Policy(compute_dtype=scfg.cache_dtype)
        )
    if ctx.conv_backend is None and scfg.conv_backend is not None:
        ctx = dataclasses.replace(ctx, conv_backend=scfg.conv_backend)
    return ctx


def serve_step(params, cfg: ModelConfig, token, caches, ctx=None):
    """(B,) int32 new token -> (logits (B, V), updated caches)."""
    return lm.decode_step(params, cfg, token, caches, ctx=ctx)


def _replicate_logits(logits, ctx):
    """The LM head leaves logits vocab-sharded over 'model'; sampling
    argsorts over V, so gather the small (S, V) block once per step instead
    of letting GSPMD re-derive a layout per sort."""
    mesh = getattr(ctx, "mesh", None)
    if mesh is None:
        return logits
    return jax.lax.with_sharding_constraint(logits, NamedSharding(mesh, P()))


# ------------------------------------------------------------- PRNG streams
#
# Every request owns a deterministic key stream indexed by (base seed, rid,
# token index), so sampled outputs are a pure function of the request — not
# of the slot it landed in, the pool composition, or eviction timing.

def request_token_key(base_key, rid, token_index):
    return jax.random.fold_in(jax.random.fold_in(base_key, rid), token_index)


# ---------------------------------------------------------- static batching

def generate(
    params,
    cfg: ModelConfig,
    prompts: jax.Array,  # (B, L_prompt) int32
    *,
    scfg: ServeConfig,
    max_new_tokens: int,
    frontend_embeds: Optional[jax.Array] = None,
    key=None,
) -> jax.Array:
    """Greedy / sampled continuation. Returns (B, max_new_tokens).

    Static batch: one prompt length, one horizon, one sampling config for
    the whole batch — the padded baseline ``ServeEngine`` improves on."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ctx = scfg.apply_context()
    params = ctx.cast_compute(params)  # policy-cast, same as ServeEngine
    compute = ctx.compute_dtype or scfg.cache_dtype
    logits, caches = lm.prefill(
        params, cfg, prompts, scfg.max_len, frontend_embeds,
        dtype=scfg.cache_dtype, compute_dtype=compute, ctx=ctx,
    )
    first = sample(key, logits[:, -1], temperature=scfg.temperature,
                   top_k=scfg.top_k)

    def body(carry, k):
        token, caches = carry
        lg, caches = lm.decode_step(
            params, cfg, token, caches, compute_dtype=compute, ctx=ctx,
        )
        nxt = sample(k, lg, temperature=scfg.temperature, top_k=scfg.top_k)
        return (nxt, caches), token

    keys = jax.random.split(key, max_new_tokens)
    (_, _), tokens = jax.lax.scan(body, (first, caches), keys)
    return tokens.T  # (B, T)


# ------------------------------------------------------ continuous batching
#
# The jitted workers are module-level so the jax.jit cache is shared across
# ServeEngine instances (per (cfg, ctx, dtype, shape) — not per engine).
# The pool is donated through every jitted update (decode / insert / reset):
# the engine never touches the previous pool again, so XLA can update the
# cache buffers in place instead of doubling the serving high-water mark.
# CPU ignores donation, so the donating wrappers are built lazily on first
# use (jax.default_backend() at import time would force backend init as an
# import side effect) and gated to avoid the unused-donation warning.


@functools.lru_cache(maxsize=1)
def _donate_pool_args() -> bool:
    return jax.default_backend() != "cpu"


@functools.partial(
    jax.jit, static_argnames=("cfg", "ctx", "dtype", "max_len", "faulty")
)
def _prefill_and_sample(
    params, prompt, temp, topk, rid, count, base_key, poison,
    *, cfg: ModelConfig, ctx, dtype, max_len: int, faulty: bool = False,
):
    """Prefill one request (batch 1) and sample its first token with the
    request's own key stream.  Returns (token (), ok (), cache), where
    ``ok`` is the always-on finite guard over the last-token logits — the
    NaN-quarantine trigger for the admission prefill (DESIGN.md §13).

    ``faulty`` is static: engines without logit-poisoning fault injection
    compile the exact program they had before (``poison`` unused, DCE'd);
    chaos engines add the scalar to the logits row before the guard.

    Under a mesh context this is the tensor-parallel prefill: activations
    follow the ``ctx.shard`` constraints, long prompts route through the
    sequence-parallel ``fft_sp`` conv past ``ctx.sp_threshold()``, and the
    last-token logits are gathered before sampling.

    NOTE: jit specializes on the exact prompt length, so a server seeing
    unbounded distinct lengths accumulates one compile per length.  Length
    bucketing is NOT a drop-in fix: left-padding would feed pad tokens into
    the conv / recurrent mixer states (only attention can mask them), so a
    bounded-compile prefill needs per-mixer pad masking first."""
    compute = getattr(ctx, "compute_dtype", None) or dtype
    logits, cache = lm.prefill(
        params, cfg, prompt, max_len, dtype=dtype, compute_dtype=compute,
        ctx=ctx,
    )
    key = request_token_key(base_key, rid, count)
    lg = _replicate_logits(logits[:, -1], ctx)
    if faulty:
        lg = lg + poison
    ok = jnp.all(jnp.isfinite(lg))
    tok = sample_slots(key[None], lg, temp, topk)
    return tok[0], ok, cache


def _decode_and_sample_impl(
    params, tokens, caches, active, temps, topks, rids, counts, base_key,
    poison,
    *, cfg: ModelConfig, ctx, dtype, quantum: int,
    sampled: bool, truncated: bool, faulty: bool = False,
):
    """``quantum`` slot-masked decode steps over the whole pool (one fused
    lax.scan) + per-slot sampling.  Returns (tokens (quantum, S),
    finite (quantum, S), final caches) — ``finite`` is the always-on
    per-slot NaN-quarantine guard (True for inactive slots), one
    ``isfinite`` reduce over each step's logits (DESIGN.md §13).

    Inactive slots run the same XLA program (static shapes) but their cache
    update is masked out, keeping free slots exactly at their reset state.
    Sampling keys derive from (rid, token index), so the emitted stream is
    independent of the quantum size and of pool composition.  ``sampled``
    (static, False when every resident request is greedy) skips the
    per-slot top-k sorts and gumbel draw entirely on the common
    temperature-0 path.

    Under a mesh context the pool stays sharded through the scan (the
    engine constrains it to the rule-derived layout at entry and exit) and
    the vocab-sharded logits are gathered before sampling.

    ``faulty`` is static: without logit-poisoning fault injection the scan
    carries no xs and the program is unchanged.  Poison is applied to the
    *logits* after the cache update — injected NaN/Inf corrupts the token
    stream (which quarantine then truncates and replays via continuation
    prefill), never the cache buffers of batch neighbors.
    """
    compute = getattr(ctx, "compute_dtype", None) or dtype

    def body(carry, xs):
        tok, caches, counts = carry
        logits, new_caches = lm.decode_step(
            params, cfg, tok, caches, compute_dtype=compute, ctx=ctx,
        )
        logits = _replicate_logits(logits, ctx)
        new_caches = lm.mask_slots(cfg, new_caches, caches, active)
        if faulty:
            logits = logits + xs[:, None]  # per-slot poison column
        finite = (~active) | jnp.all(jnp.isfinite(logits), axis=-1)
        if sampled:
            keys = jax.vmap(
                lambda r, c: request_token_key(base_key, r, c)
            )(rids, counts)
            nxt = sample_slots(keys, logits, temps, topks,
                               use_top_k=truncated)
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(active, nxt, 0)
        return (
            (nxt, new_caches, counts + active.astype(jnp.int32)),
            (nxt, finite),
        )

    (_, caches, _), (toks, finite) = jax.lax.scan(
        body, (tokens, caches, counts), poison if faulty else None,
        length=quantum,
    )
    return toks, finite, caches


def _pool_insert_impl(caches, slot, one, *, cfg: ModelConfig):
    return lm.slot_insert(cfg, caches, slot, one)


def _pool_reset_impl(caches, slot, *, cfg: ModelConfig):
    return lm.slot_reset(cfg, caches, slot)


@functools.lru_cache(maxsize=None)
def _jitted_pool_ops():
    """Build the pool-donating jitted workers once, at first use — one
    shared jit cache per process, backend queried lazily."""
    donate = _donate_pool_args()
    decode = jax.jit(
        _decode_and_sample_impl,
        static_argnames=(
            "cfg", "ctx", "dtype", "quantum", "sampled", "truncated",
            "faulty",
        ),
        donate_argnums=(2,) if donate else (),
    )
    insert = jax.jit(
        _pool_insert_impl, static_argnames=("cfg",),
        donate_argnums=(0,) if donate else (),
    )
    reset = jax.jit(
        _pool_reset_impl, static_argnames=("cfg",),
        donate_argnums=(0,) if donate else (),
    )
    return decode, insert, reset


class ServeEngine(Backend):
    """Continuous-batching serve engine: ``submit() / step() / drain()``.

    One engine owns one slot pool.  ``submit`` enqueues a request (FIFO);
    every ``step`` admits queued requests into free slots (one exact-length
    prefill each, scattered into the pool through the mixer cache-slot
    contract) and runs a single jitted decode step over all active slots.
    Greedy outputs are token-identical to per-request sequential
    :func:`generate` (property-tested for every decode-capable mixer
    pattern); sampled requests are schedule-independent — a deterministic
    function of ``(seed, rid, token index)``, never of slot placement or
    pool composition — but draw a different key stream than ``generate``'s
    batch-wide ``jax.random.split``.

    ``stream`` callbacks fire per emitted token as ``cb(rid, token, done)``.

    Mesh-native serving: pass ``ectx`` (an ``ExecutionContext`` with a
    mesh) and, to place the weights, the ``param_axes`` tree from
    ``split_params``.  The slot pool is then held in the rule-derived
    sharded layout, the decode quantum runs tensor-parallel, and outputs
    stay token-identical to the meshless engine.
    """

    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 *, seed: int = 0,
                 ectx: Optional[ExecutionContext] = None, param_axes=None,
                 injector: Optional[FaultInjector] = None):
        for m in cfg.pattern:
            if not get_mixer(m).supports_decode:
                raise ValueError(
                    f"mixer '{m}' does not support decode; cannot serve "
                    f"pattern {cfg.pattern}"
                )
        if cfg.frontend or cfg.frontend_len:
            # submit() has no frontend_embeds path: prompts would silently
            # embed frontend positions as ordinary tokens
            raise ValueError(
                "ServeEngine does not support modality-frontend configs; "
                "strip the frontend (frontend=None, frontend_len=0) or use "
                "the static generate(frontend_embeds=...) path"
            )
        self.cfg = cfg
        self.scfg = scfg
        ctx = resolve_serve_context(scfg, ectx)
        self.ctx = ctx
        params = ctx.cast_compute(params)  # serving holds policy-cast weights
        if ctx.mesh is not None and param_axes is not None:
            params = ctx.place(
                params, ctx.param_shardings(param_axes, params)
            )
        self.params = params
        self._base_key = jax.random.PRNGKey(seed)
        S = scfg.n_slots
        self.scheduler = Scheduler(S)
        self.pool = None  # built lazily from the first prefill's cache
        self._pool_shardings = None  # rule-derived, mesh engines only
        self._mesh_ops = None  # per-engine jitted (decode, insert, reset)
        self._last_tok = np.zeros((S,), np.int32)  # last emitted, per slot
        self._requests: Dict[int, Request] = {}  # queued + resident only
        self._final: Dict[int, RequestResult] = {}  # terminal outcomes
        self._next_rid = 0
        # --- failure-domain state (DESIGN.md §13)
        self.injector = injector
        # static per engine: chaos engines that poison logits compile the
        # poison-threading program once; everyone else keeps the old one
        self._faulty = injector is not None and injector.poisons
        self._tick = 0
        self._prefill_seq = 0  # monotone prefill-dispatch counter (coins)
        self._pending_quarantine: List[int] = []  # rids flagged this tick
        self.n_quarantined = 0
        self.n_retried = 0  # transient step/prefill errors absorbed
        self.n_shed = 0
        self._straggler = StragglerMonitor()
        self._heartbeat = None
        if scfg.heartbeat_path is not None:
            self._heartbeat = Heartbeat(scfg.heartbeat_path)
            self._heartbeat.beat()  # liveness file exists from construction

    # ------------------------------------------------------------- public
    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int,
        temperature: Optional[float] = None,
        top_k: Optional[int] = None,
        stop_tokens: Sequence[int] = (),
        stream: Optional[Callable[[int, int, bool], None]] = None,
        deadline: Optional[int] = None,
    ) -> int:
        """Enqueue a request; returns its rid.  Generation starts at the
        next ``step()``.

        ``deadline`` is an absolute engine tick (see ``health()['tick']``):
        if the request hasn't finished by the end of that tick it aborts
        with ``RequestResult(status="deadline_exceeded")`` and partial
        tokens.  Under overload (``scfg.overload_threshold``) the weakest
        queued arrival — possibly this one — is shed with status "shed";
        check ``result(rid)``."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len {self.scfg.max_len}"
            )
        sp = SamplingParams(
            max_new_tokens=int(max_new_tokens),
            temperature=self.scfg.temperature if temperature is None
            else float(temperature),
            top_k=self.scfg.top_k if top_k is None else int(top_k),
            stop_tokens=tuple(int(t) for t in stop_tokens),
        )
        rid = self._next_rid
        self._next_rid += 1
        if deadline is not None and int(deadline) <= self._tick:
            # already expired at submission: structured abort, no residency
            self._final[rid] = RequestResult(
                rid, "deadline_exceeded", (),
                f"deadline {deadline} <= tick {self._tick} at submit",
            )
            return rid
        req = Request(rid=rid, prompt=prompt, params=sp, stream=stream,
                      deadline=None if deadline is None else int(deadline))
        self._requests[rid] = req
        self.scheduler.submit(req)
        self._shed_overload()
        return rid

    def step(self):
        """One scheduler tick (admissions + one pooled decode step).
        Returns the list of :class:`Event` emitted this step."""
        self._tick += 1
        t0 = time.perf_counter()
        if self.injector is not None:
            slow = self.injector.slow_step_seconds(self._tick)
            if slow:
                time.sleep(slow)
        self._enforce_deadlines()
        try:
            return self.scheduler.step(self)
        finally:
            # quarantine first (evicts poisoned residents back to the
            # readmit queue or finalizes them), then prune: a long-lived
            # engine must not retain finished Request objects (prompts,
            # token lists, stream-callback closures) forever — and a
            # raising stream callback must not leave either list pinned.
            self._process_quarantine()
            self._prune_finished()
            self._straggler.record(self._tick, time.perf_counter() - t0)
            if self._heartbeat is not None:
                self._heartbeat.beat()

    def _prune_finished(self) -> None:
        live = {r.rid for r in self.scheduler.queue}
        live |= {r.rid for r in self.scheduler.readmit}
        live |= {r.rid for r in self.scheduler.slots.values()}
        for rid in [r for r in self._requests if r not in live]:
            req = self._requests.pop(rid)
            self._finalize(req, "completed")

    # ------------------------------------------- lifecycle guards (§13)
    def _finalize(self, req: Request, status: str, detail: str = "") -> None:
        self._final[req.rid] = RequestResult(
            req.rid, status, tuple(req.tokens), detail
        )

    def _abort(self, rid: int, status: str, detail: str = "") -> bool:
        """Terminate a live (queued or resident) request with a structured
        status, releasing its slot if resident.  False if rid is unknown
        or already terminal."""
        req = self._requests.get(rid)
        if req is None:
            return False
        if req.slot >= 0:
            self.scheduler._release(req.slot, self)
            req.slot = -1
        else:
            for q in (self.scheduler.queue, self.scheduler.readmit):
                try:
                    q.remove(req)
                    break
                except ValueError:
                    pass
        del self._requests[rid]
        self._finalize(req, status, detail)
        return True

    def cancel(self, rid: int) -> bool:
        """End-to-end cancellation: queued, readmitted, or mid-decode, the
        request's slot state is released and it finalizes with partial
        tokens and ``status="cancelled"``.  False if unknown/finished."""
        return self._abort(rid, "cancelled")

    def _enforce_deadlines(self) -> None:
        expired = [
            rid for rid, req in self._requests.items()
            if req.deadline is not None and self._tick > req.deadline
        ]
        for rid in expired:
            dl = self._requests[rid].deadline
            self._abort(rid, "deadline_exceeded",
                        f"deadline tick {dl} < tick {self._tick}")

    def _queue_depth(self) -> int:
        return len(self.scheduler.queue) + len(self.scheduler.readmit)

    def _shed_overload(self) -> None:
        """Reject the weakest queued work past the overload threshold.
        The dense queue is FIFO (no priority classes), so the weakest
        arrival is the newest; readmitted requests are never shed (their
        partial decode is work worth preserving)."""
        thr = self.scfg.overload_threshold
        if thr <= 0:
            return
        while self._queue_depth() > thr and self.scheduler.queue:
            victim = self.scheduler.queue[-1]
            self._abort(victim.rid, "shed",
                        f"queue depth {self._queue_depth()} > {thr}")
            self.n_shed += 1

    def _process_quarantine(self) -> None:
        """Handle slots whose decode-quantum logits went non-finite this
        tick: the request is evicted (slot state released) and replayed
        from its last good token via a continuation prefill — the
        ``(seed, rid, token_index)`` key streams make the replay
        token-identical — or finalized ``status="failed"`` once it has
        struck out (``scfg.quarantine_strikes``) or cannot be replayed
        (MoE breaks prefill/decode parity on readmission)."""
        pending, self._pending_quarantine = self._pending_quarantine, []
        for rid in pending:
            req = self._requests.get(rid)
            if req is None or req.slot < 0:
                continue  # finished before the poisoned step — moot
            req.quarantines += 1
            self.n_quarantined += 1
            if self.cfg.moe:
                self._abort(rid, "failed",
                            "non-finite logits; MoE cannot replay "
                            "(no continuation parity)")
            elif req.quarantines >= self.scfg.quarantine_strikes:
                self._abort(rid, "failed",
                            f"non-finite logits after "
                            f"{req.quarantines} quarantine strike(s)")
            else:
                self.scheduler.evict(rid, self)  # replay from last-good

    def health(self) -> Dict[str, Any]:
        """Liveness/saturation surface for an external controller
        (DESIGN.md §13): queue depths, terminal counts, quarantine /
        retry / shed counters, and stuck-step detection (EWMA straggler
        monitor over step wall-times)."""
        return {
            "tick": self._tick,
            "queued": len(self.scheduler.queue),
            "readmit": len(self.scheduler.readmit),
            "resident": len(self.scheduler.slots),
            "finished": len(self._final),
            "quarantined": self.n_quarantined,
            "retried": self.n_retried,
            "shed": self.n_shed,
            "stragglers": self._straggler.stragglers,
            "last_straggler": self._straggler.last_report,
            "heartbeat": self.scfg.heartbeat_path,
        }

    def evict(self, rid: int) -> bool:
        """Preempt a resident request back to the admission queue (its slot
        is reset; generation resumes via a continuation prefill)."""
        if self.cfg.moe:
            # continuation relies on prefill/decode parity; MoE capacity-
            # based token dropping is batch-shape-dependent, so a
            # readmission prefill would diverge from the uninterrupted
            # decode (DESIGN.md §4 I2 excludes MoE for exactly this reason)
            raise ValueError(
                "eviction-with-continuation is unsupported for MoE "
                "configs: capacity-based token dropping breaks "
                "prefill/decode parity on readmission"
            )
        return self.scheduler.evict(rid, self)

    def drain(self, max_steps: int = 100_000) -> Dict[int, np.ndarray]:
        """Step until queue and pool are empty; returns rid -> tokens.
        Raises :class:`DrainExhausted` — carrying the partial rid -> tokens
        map and the still-active rids — if the budget runs out first."""
        steps = 0
        while not self.scheduler.idle:
            self.step()
            steps += 1
            if steps > max_steps:
                active = sorted(
                    {r.rid for r in self.scheduler.queue}
                    | {r.rid for r in self.scheduler.readmit}
                    | {r.rid for r in self.scheduler.slots.values()}
                )
                partial = self.results()
                # release the unfinished residents' slot state BEFORE
                # raising so an abandoning caller doesn't leak the pool:
                # eviction resets each slot (pool back to all-free zeros)
                # and readmits the request, so the engine stays resumable.
                # MoE can't evict-with-continuation; its residents stay.
                if not self.cfg.moe:
                    for rid in [r.rid for r in
                                self.scheduler.slots.values()]:
                        self.scheduler.evict(rid, self)
                raise DrainExhausted(max_steps, partial, active)
        return self.results()

    def results(self) -> Dict[int, np.ndarray]:
        """Finished outputs plus the partial tokens of in-flight requests."""
        out = {
            rid: np.asarray(res.tokens, np.int32)
            for rid, res in self._final.items()
        }
        out.update({
            rid: np.asarray(req.tokens, np.int32)
            for rid, req in self._requests.items()
        })
        return out

    def pop_result(self, rid: int) -> np.ndarray:
        """Take (and forget) a finished request's tokens — the retention
        valve for servers that run one engine indefinitely."""
        return np.asarray(self._final.pop(rid).tokens, np.int32)

    def result(self, rid: int) -> Optional[RequestResult]:
        """The structured terminal outcome of ``rid`` (None while live)."""
        return self._final.get(rid)

    def request_results(self) -> Dict[int, RequestResult]:
        """All terminal outcomes so far (rid -> :class:`RequestResult`)."""
        return dict(self._final)

    # --------------------------------------------------- pool op selection
    def _pool_ops(self):
        """(decode, insert, reset) jitted workers.  Meshless engines share
        the module-level jit cache; mesh engines build per-engine wrappers
        that pin the pool to its rule-derived sharded layout on entry and
        exit (donation then updates the sharded buffers in place)."""
        if self.ctx.mesh is None:
            return _jitted_pool_ops()
        if self._mesh_ops is None:
            shardings = self._pool_shardings

            def constrain(caches):
                return jax.tree_util.tree_map(
                    lambda s, x: jax.lax.with_sharding_constraint(x, s),
                    shardings, caches,
                )

            def decode_impl(params, tokens, caches, active, temps, topks,
                            rids, counts, base_key, poison, *, cfg, ctx,
                            dtype, quantum, sampled, truncated,
                            faulty=False):
                toks, finite, out = _decode_and_sample_impl(
                    params, tokens, constrain(caches), active, temps,
                    topks, rids, counts, base_key, poison, cfg=cfg,
                    ctx=ctx, dtype=dtype, quantum=quantum, sampled=sampled,
                    truncated=truncated, faulty=faulty,
                )
                return toks, finite, constrain(out)

            def insert_impl(caches, slot, one, *, cfg):
                return constrain(
                    _pool_insert_impl(constrain(caches), slot, one, cfg=cfg)
                )

            def reset_impl(caches, slot, *, cfg):
                return constrain(
                    _pool_reset_impl(constrain(caches), slot, cfg=cfg)
                )

            donate = _donate_pool_args()
            self._mesh_ops = (
                jax.jit(
                    decode_impl,
                    static_argnames=(
                        "cfg", "ctx", "dtype", "quantum", "sampled",
                        "truncated", "faulty",
                    ),
                    donate_argnums=(2,) if donate else (),
                ),
                jax.jit(
                    insert_impl, static_argnames=("cfg",),
                    donate_argnums=(0,) if donate else (),
                ),
                jax.jit(
                    reset_impl, static_argnames=("cfg",),
                    donate_argnums=(0,) if donate else (),
                ),
            )
        return self._mesh_ops

    # ----------------------------------------------- scheduler Backend API
    def prefill_into_slot(self, slot: int, req: Request) -> Optional[int]:
        prompt = req.resume_prompt[None, :]  # (1, L) exact length
        while True:
            attempt = [0]

            def dispatch():
                a = attempt[0]
                attempt[0] += 1
                if self.injector is not None:
                    # coins keyed by a monotone dispatch counter, so the
                    # readmit path after retry exhaustion draws fresh
                    # coins (deterministic, but never the same coin twice)
                    self._prefill_seq += 1
                    self.injector.check_prefill(
                        self._tick, req.rid, self._prefill_seq
                    )
                poison = (
                    self.injector.poison_value(
                        req.rid, req.n_emitted, req.quarantines
                    ) if self._faulty else 0.0
                )
                with self.ctx.scope():
                    return _prefill_and_sample(
                        self.params, jnp.asarray(prompt),
                        jnp.asarray([req.params.temperature], jnp.float32),
                        jnp.asarray([req.params.top_k], jnp.int32),
                        jnp.asarray(req.rid, jnp.int32),
                        jnp.asarray(req.n_emitted, jnp.int32),
                        self._base_key,
                        jnp.asarray(poison, jnp.float32),
                        cfg=self.cfg, ctx=self.ctx,
                        dtype=self.scfg.cache_dtype,
                        max_len=self.scfg.max_len, faulty=self._faulty,
                    )

            try:
                tok, ok, cache = retry(
                    dispatch, attempts=self.scfg.step_retry_attempts,
                    base_delay=self.scfg.step_retry_base_delay,
                    exceptions=(TransientStepError,),
                )
            except TransientStepError:
                # transient failure survived every retry: requeue ahead of
                # arrivals and hand the slot back (scheduler None
                # contract) — the next admission draws fresh coins
                self.n_retried += attempt[0] - 1
                self.scheduler.readmit.append(req)
                return None
            self.n_retried += attempt[0] - 1
            if bool(ok):
                break
            # non-finite prefill logits: a quarantine strike.  Replay is
            # just re-prefilling (same resume prompt, fresh poison coins
            # via the bumped attempt) — or structured failure on
            # strike-out / MoE (no continuation parity to lean on).
            req.quarantines += 1
            self.n_quarantined += 1
            if (self.cfg.moe
                    or req.quarantines >= self.scfg.quarantine_strikes):
                self._requests.pop(req.rid, None)
                self._finalize(
                    req, "failed",
                    f"non-finite prefill logits after "
                    f"{req.quarantines} quarantine strike(s)",
                )
                return None
        with self.ctx.scope():
            if self.pool is None:
                pool = lm.make_slot_pool(self.cfg, cache, self.scfg.n_slots)
                if self.ctx.mesh is not None:
                    # the pool is born in the rule-derived sharded layout
                    # (model-axis heads/channels, replicated cursors) and
                    # every jitted update keeps it there
                    self._pool_shardings = self.ctx.cache_shardings(
                        self.cfg, pool
                    )
                    pool = self.ctx.place(pool, self._pool_shardings)
                self.pool = pool
            _, insert, _ = self._pool_ops()
            self.pool = insert(
                self.pool, jnp.asarray(slot, jnp.int32), cache, cfg=self.cfg
            )
        tok = int(tok)
        self._last_tok[slot] = tok
        return tok

    def decode_active(self, requests: Dict[int, Request]):
        S = self.scfg.n_slots
        active = np.zeros((S,), bool)
        temps = np.zeros((S,), np.float32)
        topks = np.zeros((S,), np.int32)
        rids = np.zeros((S,), np.int32)
        counts = np.zeros((S,), np.int32)
        quantum = self.scfg.decode_quantum
        for slot, req in requests.items():
            active[slot] = True
            temps[slot] = req.params.temperature
            topks[slot] = req.params.top_k
            rids[slot] = req.rid
            counts[slot] = req.n_emitted  # index of the token sampled now
        poison = np.zeros((quantum, S), np.float32)
        if self._faulty:
            for slot, req in requests.items():
                for i in range(quantum):
                    poison[i, slot] = self.injector.poison_value(
                        req.rid, req.n_emitted + i, req.quarantines
                    )
        decode, _, _ = self._pool_ops()
        attempt = [0]

        def dispatch():
            a = attempt[0]
            attempt[0] += 1
            if self.injector is not None:
                # raises BEFORE the jitted call dispatches: a failed
                # attempt never consumes the donated pool buffers
                self.injector.check_step(self._tick, a)
            with self.ctx.scope():
                return decode(
                    self.params, jnp.asarray(self._last_tok), self.pool,
                    jnp.asarray(active), jnp.asarray(temps),
                    jnp.asarray(topks), jnp.asarray(rids),
                    jnp.asarray(counts), self._base_key,
                    jnp.asarray(poison),
                    cfg=self.cfg, ctx=self.ctx, dtype=self.scfg.cache_dtype,
                    quantum=quantum,
                    sampled=bool((temps > 0.0).any()),
                    truncated=bool((topks > 0).any()),
                    faulty=self._faulty,
                )

        toks, finite, self.pool = retry(
            dispatch, attempts=self.scfg.step_retry_attempts,
            base_delay=self.scfg.step_retry_base_delay,
            exceptions=(TransientStepError,),
        )
        self.n_retried += attempt[0] - 1
        toks = np.asarray(toks)  # (quantum, S)
        finite = np.asarray(finite)  # (quantum, S) bool
        out: Dict[int, list] = {}
        for slot, req in requests.items():
            self._last_tok[slot] = int(toks[-1, slot])
            col = finite[:, slot]
            if col.all():
                out[slot] = [int(t) for t in toks[:, slot]]
            else:
                # truncate at the first non-finite step: everything before
                # it is good (kept; replay resumes after it), everything
                # from it on is poisoned garbage
                good = int(np.argmax(~col))
                out[slot] = [int(t) for t in toks[:good, slot]]
                self._pending_quarantine.append(req.rid)
        return out

    def reset_slot(self, slot: int) -> None:
        if self.pool is not None:
            _, _, reset = self._pool_ops()
            with self.ctx.scope():
                self.pool = reset(
                    self.pool, jnp.asarray(slot, jnp.int32), cfg=self.cfg
                )
