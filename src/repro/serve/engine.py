"""Batched serving engine: prefill → jitted decode loop over per-mixer
caches (KV ring buffers for attention, O(L) conv cache for Hyena, O(1)
recurrent state for SSD / RG-LRU).

``serve_step`` — one new token against a populated cache — is exactly what
the multi-pod dry-run lowers for the ``decode_32k`` / ``long_500k`` cells.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.mixer_api import ApplyContext
from repro.serve.sampling import sample


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    temperature: float = 0.0
    top_k: int = 0
    cache_dtype: Any = jnp.bfloat16
    # hyena long-conv backend for the *prefill* pass (decode steps are
    # cached dots — no long conv to select)
    conv_backend: Optional[str] = None

    def __post_init__(self):
        self.apply_context()  # unknown backend names fail here, not on the
        # first generate() of a deployed server

    def apply_context(self) -> ApplyContext:
        """Serving's single resolution point for execution options."""
        return ApplyContext(conv_backend=self.conv_backend)


def serve_step(params, cfg: ModelConfig, token, caches,
               ctx: Optional[ApplyContext] = None):
    """(B,) int32 new token -> (logits (B, V), updated caches)."""
    return lm.decode_step(params, cfg, token, caches, ctx=ctx)


def generate(
    params,
    cfg: ModelConfig,
    prompts: jax.Array,  # (B, L_prompt) int32
    *,
    scfg: ServeConfig,
    max_new_tokens: int,
    frontend_embeds: Optional[jax.Array] = None,
    key=None,
) -> jax.Array:
    """Greedy / sampled continuation. Returns (B, max_new_tokens)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ctx = scfg.apply_context()
    logits, caches = lm.prefill(
        params, cfg, prompts, scfg.max_len, frontend_embeds,
        dtype=scfg.cache_dtype, ctx=ctx,
    )
    first = sample(key, logits[:, -1], temperature=scfg.temperature,
                   top_k=scfg.top_k)

    def body(carry, k):
        token, caches = carry
        lg, caches = lm.decode_step(params, cfg, token, caches, ctx=ctx)
        nxt = sample(k, lg, temperature=scfg.temperature, top_k=scfg.top_k)
        return (nxt, caches), token

    keys = jax.random.split(key, max_new_tokens)
    (_, _), tokens = jax.lax.scan(body, (first, caches), keys)
    return tokens.T  # (B, T)
