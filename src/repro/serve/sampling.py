"""Token sampling for batched decode.

Two entry points share one masking core:

  * :func:`sample` — one (temperature, top_k) for the whole batch (the
    static ``generate`` path and per-request prefill sampling).
  * :func:`sample_slots` — per-row temperature / top_k / PRNG key, used by
    the continuous-batching engine where every slot is an independent
    request with its own sampling params and key stream.

Top-k keeps **exactly** k candidates: candidates are ranked by a stable
descending argsort, so duplicate kth-value logits are tie-broken toward the
lower token id instead of all being admitted (the old ``logits < kth``
threshold kept every tied candidate, silently widening the nucleus).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def top_k_mask(logits: jax.Array, top_k) -> jax.Array:
    """Mask all but the top-k logits per row to NEG_INF.

    ``logits``: (..., V); ``top_k``: scalar or (...,) int — 0 keeps all.
    Exactly k survive per row: ties at the kth value are broken by token id
    (stable argsort), deterministically.
    """
    V = logits.shape[-1]
    order = jnp.argsort(-logits, axis=-1)  # stable: ties -> lower id first
    ranks = jnp.argsort(order, axis=-1)  # rank of each token id
    k = jnp.asarray(top_k, jnp.int32)
    k = jnp.where(k > 0, k, V)[..., None]
    return jnp.where(ranks < k, logits, NEG_INF)


def sample(
    key, logits: jax.Array, *, temperature: float = 0.0, top_k: int = 0
) -> jax.Array:
    """logits: (B, V) -> tokens (B,).  temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:  # static here — skip the O(V log V) sorts when untruncated
        logits = top_k_mask(logits, top_k)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_slots(
    keys,  # (S,) PRNG keys (stacked, one per slot)
    logits: jax.Array,  # (S, V)
    temperature: jax.Array,  # (S,) float; <= 0 -> greedy for that slot
    top_k: jax.Array,  # (S,) int; 0 -> no truncation
    *,
    use_top_k: bool = True,  # static: False skips the O(V log V) sorts
) -> jax.Array:
    """Per-slot sampling in one fused call: each row draws with its own
    temperature / top-k / key, so requests with different sampling params
    coexist in one jitted decode step.  Pass ``use_top_k=False`` (a static
    Python bool) when every row has top_k == 0 to skip the rank sorts —
    the per-slot analogue of the scalar ``sample``'s ``if top_k > 0``."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    masked = top_k_mask(scaled, top_k) if use_top_k else scaled
    drawn = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)
    return jnp.where(temperature > 0.0, drawn, greedy)
