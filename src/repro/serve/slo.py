"""Priority/SLO-aware admission (DESIGN.md §11).

Replaces FIFO admission for the paged engine: requests carry a priority
class and an optional deadline, and the queue orders admission by

    (readmitted first) > priority (higher first) > deadline (earlier
    first) > arrival order

Two starvation guards, both load-bearing under overload:

  * evicted (preempted) requests re-enter through a dedicated readmit
    deque that is always drained BEFORE the priority queue — a preempted
    request can never be pushed behind a stream of new arrivals of equal
    priority (the regression the dense scheduler satellite also fixes);
  * priority preemption is one-way: an admission candidate may preempt a
    strictly lower-priority resident, and the victim re-enters the readmit
    deque, so ping-pong between equal priorities is impossible.

Deadlines are scheduler ticks (engine steps), not wall seconds: the engine
has no clock of its own, and tick-denominated deadlines keep schedules
deterministic and replayable.  ``None`` means "no deadline" and sorts last
within a priority class.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from typing import Iterator, Optional, Tuple


class SLOQueue:
    """Admission queue: readmit deque + (priority, deadline, seq) heap."""

    def __init__(self) -> None:
        self._heap: list = []  # (-priority, deadline, seq, rid)
        self._readmit: deque = deque()  # rids, FIFO
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap) + len(self._readmit)

    def __bool__(self) -> bool:
        return bool(self._heap) or bool(self._readmit)

    def push(self, rid: int, priority: int = 0,
             deadline: Optional[int] = None) -> None:
        key = math.inf if deadline is None else float(deadline)
        heapq.heappush(self._heap, (-int(priority), key, self._seq, rid))
        self._seq += 1

    def push_readmit(self, rid: int) -> None:
        """Re-enter a preempted request AHEAD of every queued arrival
        (relative readmit order preserved — FIFO among the preempted)."""
        self._readmit.append(rid)

    def peek(self) -> Optional[Tuple[int, bool]]:
        """(rid, is_readmit) of the next admission candidate, or None."""
        if self._readmit:
            return self._readmit[0], True
        if self._heap:
            return self._heap[0][3], False
        return None

    def pop(self) -> Optional[int]:
        if self._readmit:
            return self._readmit.popleft()
        if self._heap:
            return heapq.heappop(self._heap)[3]
        return None

    def peek_priority(self) -> Optional[int]:
        """Priority of the best queued (non-readmit) arrival — the
        preemption trigger compares this against resident priorities.
        Readmitted requests never trigger further preemption (one-way)."""
        if self._heap:
            return -self._heap[0][0]
        return None

    def rids(self) -> Iterator[int]:
        yield from self._readmit
        for _, _, _, rid in sorted(self._heap):
            yield rid

    def remove(self, rid: int) -> bool:
        """Drop a queued request (cancellation); O(n), rare path."""
        try:
            self._readmit.remove(rid)
            return True
        except ValueError:
            pass
        for i, ent in enumerate(self._heap):
            if ent[3] == rid:
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                heapq.heapify(self._heap)
                return True
        return False
