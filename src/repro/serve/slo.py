"""Priority/SLO-aware admission (DESIGN.md §11, §13).

Replaces FIFO admission for the paged engine: requests carry a priority
class and an optional deadline, and the queue orders admission by

    (readmitted first) > priority (higher first) > deadline (earlier
    first) > arrival order

Two starvation guards, both load-bearing under overload:

  * evicted (preempted) requests re-enter through a dedicated readmit
    deque that is always drained BEFORE the priority queue — a preempted
    request can never be pushed behind a stream of new arrivals of equal
    priority (the regression the dense scheduler satellite also fixes);
  * priority preemption is one-way: an admission candidate may preempt a
    strictly lower-priority resident, and the victim re-enters the readmit
    deque, so ping-pong between equal priorities is impossible.

Deadlines are scheduler ticks (engine steps), not wall seconds: the engine
has no clock of its own, and tick-denominated deadlines keep schedules
deterministic and replayable.  ``None`` means "no deadline" and sorts last
within a priority class.

Removal (cancellation, deadline aborts, load shedding) is *lazy*: a
removed rid lands in a tombstone set and its heap/deque entry is skipped —
and discarded — when it reaches the front, so ``remove`` is O(1) and
``pop``/``peek`` stay O(log n) amortized.  The old implementation rebuilt
the whole heap per removal (O(n) + heapify), which made cancellation
storms quadratic.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from typing import Iterator, Optional, Tuple


class SLOQueue:
    """Admission queue: readmit deque + (priority, deadline, seq) heap,
    with lazy-tombstone removal."""

    def __init__(self) -> None:
        self._heap: list = []  # (-priority, deadline, seq, rid)
        self._readmit: deque = deque()  # rids, FIFO
        self._seq = 0
        self._live: set = set()  # rids currently queued (heap + readmit)
        self._tombstones: set = set()  # removed rids whose entries remain

    def __len__(self) -> int:
        return len(self._live)

    def __bool__(self) -> bool:
        return bool(self._live)

    # ------------------------------------------------- lazy-removal core
    def _settle_heap(self) -> None:
        """Pop tombstoned entries off the heap top (amortized O(log n):
        each removed entry is popped exactly once, here)."""
        while self._heap and self._heap[0][3] in self._tombstones:
            rid = heapq.heappop(self._heap)[3]
            self._tombstones.discard(rid)

    def _settle_readmit(self) -> None:
        while self._readmit and self._readmit[0] in self._tombstones:
            self._tombstones.discard(self._readmit.popleft())

    # ------------------------------------------------------------- push
    def push(self, rid: int, priority: int = 0,
             deadline: Optional[int] = None) -> None:
        key = math.inf if deadline is None else float(deadline)
        heapq.heappush(self._heap, (-int(priority), key, self._seq, rid))
        self._seq += 1
        self._live.add(rid)
        self._tombstones.discard(rid)

    def push_readmit(self, rid: int) -> None:
        """Re-enter a preempted request AHEAD of every queued arrival
        (relative readmit order preserved — FIFO among the preempted)."""
        self._readmit.append(rid)
        self._live.add(rid)
        self._tombstones.discard(rid)

    # ------------------------------------------------------------ peeks
    def peek(self) -> Optional[Tuple[int, bool]]:
        """(rid, is_readmit) of the next admission candidate, or None."""
        self._settle_readmit()
        if self._readmit:
            return self._readmit[0], True
        self._settle_heap()
        if self._heap:
            return self._heap[0][3], False
        return None

    def pop(self) -> Optional[int]:
        self._settle_readmit()
        if self._readmit:
            rid = self._readmit.popleft()
            self._live.discard(rid)
            return rid
        self._settle_heap()
        if self._heap:
            rid = heapq.heappop(self._heap)[3]
            self._live.discard(rid)
            return rid
        return None

    def peek_priority(self) -> Optional[int]:
        """Priority of the best queued (non-readmit) arrival — the
        preemption trigger compares this against resident priorities.
        Readmitted requests never trigger further preemption (one-way)."""
        self._settle_heap()
        if self._heap:
            return -self._heap[0][0]
        return None

    def rids(self) -> Iterator[int]:
        for rid in self._readmit:
            if rid not in self._tombstones:
                yield rid
        for _, _, _, rid in sorted(self._heap):
            if rid not in self._tombstones:
                yield rid

    # --------------------------------------------------------- removal
    def remove(self, rid: int) -> bool:
        """Drop a queued request (cancellation / deadline abort / load
        shedding).  O(1): the entry is tombstoned and skipped when it
        surfaces.  Returns False if ``rid`` is not queued."""
        if rid not in self._live:
            return False
        self._live.discard(rid)
        self._tombstones.add(rid)
        return True

    def worst(self) -> Optional[int]:
        """The weakest queued *arrival* — lowest priority, then latest
        deadline (no deadline sorts last), then newest — the load-shedding
        victim under overload (DESIGN.md §13).  Readmitted requests are
        never shed (they already did work worth preserving); returns None
        if only readmits are queued.  O(n) scan, but shedding only runs
        past the overload threshold."""
        live = [e for e in self._heap if e[3] in self._live]
        if not live:
            return None
        return max(live)[3]
