"""Paged cache allocator + paged continuous-batching engine (DESIGN.md §11).

vLLM pages attention KV.  This engine generalizes block paging to *every*
registered TokenMixer's decode cache through one split, derived from the
``cache_page_axes`` contract (models/mixer_api.py):

  * **paged** leaves — unbounded append-only per-token state (attention
    global K/V at time axis 1, hyena's per-order conv-operand history at
    time axis 2).  Their slot axis is scattered over a pool of fixed-size
    physical *blocks* (``page_size`` tokens each) addressed by one shared
    per-slot block table; every paged leaf of every layer uses the same
    table, so allocation is a per-request decision, not a per-tensor one.
  * **pinned** leaves — bounded state (local-attention rings, short-conv
    windows, SSD/RG-LRU recurrent states, cursors): a dense per-slot pool,
    exactly like the dense engine.  For pure-recurrent patterns the paged
    set is empty and the machinery degrades gracefully (the radix prefix
    cache still works: its nodes snapshot pinned state).
  * **shared** leaves (``cache_slot_axes`` = -1, e.g. hyena's filter taps)
    — one copy, never written at decode time.

Block 0 is reserved as the *trash block*: unmapped block-table entries
point at it, so the gather/scatter of inactive or short rows needs no
masking — garbage reads land past each mixer's validity cursor (the
``cache_page_axes`` contract requires decode steps to mask positions
>= t) and garbage writes land in block 0, which is never read.

Copy-on-write: blocks are refcounted (:class:`BlockAllocator`).  Forked
prefixes (radix hits) share blocks read-only; before a quantum may write
into a page whose block is shared, the engine allocates a private block
and copies it (``_copy_blocks``).  With page-aligned prefix forks shared
blocks are never write targets, so the copy path is a safety net for
future partial-page forks (beam search) — it is unit-tested directly.

Prefill is *chunked and interleaved*: prompts are fed through the decode
path ``decode_quantum`` tokens at a time (clipped to page boundaries so
radix snapshots align), inside the same jitted pool scan that decodes
everyone else.  Under overload a long prompt therefore cannot stall
resident decodes for its full length — TTFT and inter-token latency are
bounded by the quantum, which is the SLO knob (benchmarks/bench_serving
measures both).  A welcome side effect: no per-prompt-length jit
specialization (the dense engine compiles one prefill per distinct
length); the paged engine compiles one program per (quantum, view-bucket)
pair.

Admission is priority/SLO-aware (:mod:`repro.serve.slo`) instead of FIFO,
with starvation-free readmission (preempted requests re-enter ahead of
new arrivals) and bounded priority preemption.  Token streams remain
schedule-independent: sampling keys derive from (seed, rid, token index),
identical to the dense engine's stream.

Numerics: the dense engine prefills prompts through the batched
``lm.prefill`` and is bit-identical to ``generate()``.  The paged engine
absorbs prompts through the decode path, whose outputs match prefill to
tolerance, not bit-exactly (different reduction shapes re-associate fp
sums) — so greedy argmax can legitimately flip on near-ties.  The parity
harness (tests/serve_parity.py) therefore allows a divergence only at a
genuine reference near-tie (top-2 logit gap below tolerance) and pins
fixed seeds that match exactly in float32.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.ft import Heartbeat, StragglerMonitor, retry
from repro.configs.base import ModelConfig
from repro.distributed.execution import ExecutionContext
from repro.models import lm
from repro.models.mixer_api import get_mixer, slot_insert_leaf, slot_slice_leaf
from repro.serve.engine import (
    DrainExhausted,
    ServeConfig,
    _donate_pool_args,
    _replicate_logits,
    request_token_key,
    resolve_serve_context,
)
from repro.serve.faults import FaultInjector, TransientStepError
from repro.serve.radix import RadixPrefixCache
from repro.serve.sampling import sample_slots
from repro.serve.scheduler import Event, Request, RequestResult, SamplingParams
from repro.serve.slo import SLOQueue


@dataclasses.dataclass(frozen=True)
class PagedConfig:
    """Paged-allocator knobs, orthogonal to :class:`ServeConfig`."""

    page_size: int = 8  # tokens per block
    # physical blocks incl. the reserved trash block 0; 0 = auto-size so
    # every slot can reach max_len (no paging pressure — tests/bench pass
    # smaller pools to exercise preemption and measure slots-at-memory)
    n_blocks: int = 0
    prefix_cache: bool = True  # radix prefix reuse across requests
    max_preemptions_per_step: int = 1  # priority preemptions per tick

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {self.n_blocks}")


class BlockAllocator:
    """Refcounted free-list allocator over ``n_blocks`` physical blocks.
    Block 0 is the reserved trash block and is never handed out."""

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one usable + trash)")
        self.n_blocks = n_blocks
        # pop() order: lowest id first (deterministic schedules)
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self.ref = np.zeros((n_blocks,), np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        b = self._free.pop()
        self.ref[b] = 1
        return b

    def incref(self, b: int) -> None:
        assert 0 < b < self.n_blocks and self.ref[b] > 0
        self.ref[b] += 1

    def decref(self, b: int) -> bool:
        """Returns True if the block's refcount hit zero (it is back on the
        free list; the owner must zero its contents — invariant I3)."""
        assert 0 < b < self.n_blocks and self.ref[b] > 0
        self.ref[b] -= 1
        if self.ref[b] == 0:
            self._free.append(b)
            return True
        return False


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Static (hashable) description of the flattened cache pool: which
    leaf is paged/pinned/shared and where its slot/time axes sit.  Passed
    as a jit static argument so the gather/scatter specializes per model,
    not per engine."""

    treedef: Any
    slot_axes: Tuple[int, ...]  # per flat leaf; -1 = shared
    paged_idx: Tuple[int, ...]
    pinned_idx: Tuple[int, ...]
    shared_idx: Tuple[int, ...]
    page: int


def _axes_leaves(axes_tree) -> List[Any]:
    """Flatten an axes tree whose leaves are ints / None / logical-axes
    tuples, in the same order as the value tree's leaves."""
    return jax.tree_util.tree_flatten(
        axes_tree,
        is_leaf=lambda a: a is None or isinstance(a, tuple)
        or isinstance(a, int),
    )[0]


def build_pool_spec(cfg: ModelConfig, template, page: int) -> PoolSpec:
    """Derive the paged/pinned/shared split from the mixer contracts for a
    batch-1 cache ``template``."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    slot_axes = [int(a) for a in _axes_leaves(lm.cache_slot_axes(cfg, template))]
    page_axes = [int(a) for a in _axes_leaves(lm.cache_page_axes(cfg, template))]
    assert len(slot_axes) == len(leaves) == len(page_axes)
    paged, pinned, shared = [], [], []
    for i, (s, p) in enumerate(zip(slot_axes, page_axes)):
        if s < 0:
            shared.append(i)
        elif p >= 0:
            paged.append(i)
        else:
            pinned.append(i)
    return PoolSpec(
        treedef=treedef,
        slot_axes=tuple(slot_axes),
        paged_idx=tuple(paged),
        pinned_idx=tuple(pinned),
        shared_idx=tuple(shared),
        page=int(page),
    )


# ------------------------------------------------------------ jitted ops
#
# All ops move flat *lists* of leaves (lists are pytrees): ``phys`` =
# paged leaves with slot axis -> n_blocks and time axis -> page_size,
# ``pinned`` = dense per-slot leaves, ``shared`` = single-copy leaves.
# Module-level impls + one shared lru-cached jit per process, mirroring
# repro.serve.engine's pool ops; mesh engines wrap them with sharding
# constraints.


def _assemble(spec: PoolSpec, phys, pinned, shared, table):
    """Gather the per-slot *view* cache tree: each paged leaf's blocks are
    gathered through ``table`` (S, Pv) and the (block, page) pair merges
    back into one time axis of Pv * page tokens (a truncated but layout-
    identical view of the dense cache, which every mixer's decode step
    accepts because validity is cursor-masked)."""
    leaves: List[Any] = [None] * len(spec.slot_axes)
    for j, i in enumerate(spec.paged_idx):
        s = spec.slot_axes[i]
        v = jnp.take(phys[j], table, axis=s)  # (..., S, Pv, page, ...)
        shp = v.shape
        leaves[i] = v.reshape(shp[: s + 1] + (shp[s + 1] * shp[s + 2],) + shp[s + 3:])
    for j, i in enumerate(spec.pinned_idx):
        leaves[i] = pinned[j]
    for j, i in enumerate(spec.shared_idx):
        leaves[i] = shared[j]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def _split(spec: PoolSpec, caches, phys, table):
    """Scatter a view cache tree back: paged leaves' pages return to their
    physical blocks through the flat table, pinned leaves pass through.
    Duplicate table entries are benign — shared blocks are read-only so
    every writer scatters identical bytes, and unmapped entries collide on
    the trash block 0, which is never read."""
    flat = jax.tree_util.tree_flatten(caches)[0]
    flat_table = table.reshape(-1)  # (S * Pv,)
    new_phys = []
    for j, i in enumerate(spec.paged_idx):
        s = spec.slot_axes[i]
        v = flat[i]  # (..., S, Pv * page, ...)
        shp = v.shape
        v = v.reshape(shp[:s] + (-1, spec.page) + shp[s + 2:])  # (.., S*Pv, page, ..)
        ph = jnp.moveaxis(phys[j], s, 0)
        val = jnp.moveaxis(v, s, 0)
        new_phys.append(jnp.moveaxis(ph.at[flat_table].set(val), 0, s))
    new_pinned = [flat[i] for i in spec.pinned_idx]
    return new_phys, new_pinned


def _paged_quantum_impl(
    params, phys, pinned, shared, table, feed0, feed_next,
    m, adv, t0, p0, active, temps, topks, rids, base_key, poison,
    *, cfg: ModelConfig, ctx, dtype, spec: PoolSpec, quantum: int,
    sampled: bool, truncated: bool, faulty: bool = False,
):
    """One fused quantum over the paged pool: gather block views, run
    ``quantum`` slot-masked decode steps that both absorb prompt chunks
    and decode (per-slot ``adv`` bounds progress; prompt tokens stream in
    via the scan xs), sample with the (rid, token index) key streams, and
    scatter the views back to physical blocks.

    Per slot: ``t0`` tokens already absorbed, the next ``m`` scan steps
    feed prompt tokens (``feed0`` now, ``feed_next[q]`` at step q+1),
    after which the carry switches to the slot's own samples.  A token is
    *emitted* when its sampling index ``count = t0 + q + 1 - p0`` is
    >= 0; the host discards re-derived emissions (count below what the
    request already holds) during eviction-continuation refeeds.

    Returns (tokens (quantum, S), emit mask (quantum, S), finite mask
    (quantum, S), new phys, new pinned).  ``finite`` is the always-on
    per-slot NaN-quarantine guard (True for slots not running a step);
    ``faulty`` is static — without logit-poisoning fault injection the
    scan carries no poison xs and the program is unchanged.  Poison hits
    the logits after the cache update, so injected NaN/Inf corrupts only
    the token stream (truncated + replayed by quarantine), never the
    physical blocks of batch neighbors."""
    compute = getattr(ctx, "compute_dtype", None) or dtype
    caches = _assemble(spec, phys, pinned, shared, table)

    def body(carry, xs):
        cur, caches = carry
        if faulty:
            q, nxt, pois = xs
        else:
            q, nxt = xs
        run = active & (q < adv)
        logits, new_caches = lm.decode_step(
            params, cfg, cur, caches, compute_dtype=compute, ctx=ctx,
        )
        logits = _replicate_logits(logits, ctx)
        new_caches = lm.mask_slots(cfg, new_caches, caches, run)
        if faulty:
            logits = logits + pois[:, None]  # per-slot poison column
        finite = (~run) | jnp.all(jnp.isfinite(logits), axis=-1)
        count = t0 + q + 1 - p0
        if sampled:
            keys = jax.vmap(
                lambda r, c: request_token_key(base_key, r, c)
            )(rids, count)
            samp = sample_slots(keys, logits, temps, topks,
                                use_top_k=truncated)
        else:
            samp = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        emit = run & (count >= 0)
        nxt_cur = jnp.where(q + 1 < m, nxt, samp)
        nxt_cur = jnp.where(run, nxt_cur, cur)
        return (
            (nxt_cur, new_caches),
            (jnp.where(emit, samp, 0), emit, finite),
        )

    xs = (
        (jnp.arange(quantum), feed_next, poison) if faulty
        else (jnp.arange(quantum), feed_next)
    )
    (_, caches), (toks, emits, finite) = jax.lax.scan(
        body, (feed0, caches), xs
    )
    new_phys, new_pinned = _split(spec, caches, phys, table)
    return toks, emits, finite, new_phys, new_pinned


def _copy_blocks_impl(phys, src, dst, *, spec: PoolSpec):
    """COW resolution: copy blocks ``src[k] -> dst[k]`` across every paged
    leaf.  Padding pairs are (0, 0): a self-copy of the trash block."""
    out = []
    for j, i in enumerate(spec.paged_idx):
        s = spec.slot_axes[i]
        ph = jnp.moveaxis(phys[j], s, 0)
        ph = ph.at[dst].set(ph[src])
        out.append(jnp.moveaxis(ph, 0, s))
    return out


def _zero_blocks_impl(phys, blocks, *, spec: PoolSpec):
    """Zero freed blocks (invariant I3 lifted to physical blocks); padding
    entries are 0, harmlessly re-zeroing the trash block."""
    out = []
    for j, i in enumerate(spec.paged_idx):
        s = spec.slot_axes[i]
        ph = jnp.moveaxis(phys[j], s, 0)
        ph = ph.at[blocks].set(jnp.zeros_like(ph[blocks]))
        out.append(jnp.moveaxis(ph, 0, s))
    return out


def _pinned_snapshot_impl(pinned, slot, *, spec: PoolSpec):
    """Batch-1 slices of every pinned leaf at ``slot`` — the radix node's
    forkable state (rings, conv windows, recurrent states, cursors)."""
    return [
        slot_slice_leaf(leaf, slot, spec.slot_axes[i])
        for leaf, i in zip(pinned, spec.pinned_idx)
    ]


def _pinned_restore_impl(pinned, slot, snap, *, spec: PoolSpec):
    return [
        slot_insert_leaf(leaf, one, slot, spec.slot_axes[i])
        for leaf, one, i in zip(pinned, snap, spec.pinned_idx)
    ]


def _pinned_reset_impl(pinned, slot, *, spec: PoolSpec):
    from repro.models.mixer_api import slot_zero_leaf

    return [
        slot_zero_leaf(leaf, slot, spec.slot_axes[i])
        for leaf, i in zip(pinned, spec.pinned_idx)
    ]


@functools.partial(
    jax.jit, static_argnames=("cfg", "ctx", "dtype", "max_len")
)
def _template_prefill(params, *, cfg: ModelConfig, ctx, dtype, max_len: int):
    """Batch-1 single-token prefill whose cache is the pool *template*:
    authoritative shapes/dtypes for every leaf plus real values for the
    shared leaves (hyena's decode filter taps are params-dependent — a
    zeros template would silently break every decode)."""
    compute = getattr(ctx, "compute_dtype", None) or dtype
    _, cache = lm.prefill(
        params, cfg, jnp.zeros((1, 1), jnp.int32), max_len,
        dtype=dtype, compute_dtype=compute, ctx=ctx,
    )
    return cache


@functools.lru_cache(maxsize=None)
def _jitted_paged_ops():
    """Shared-per-process jitted workers (same pattern as the dense
    engine's pool ops): specialize per static (cfg, ctx, spec, ...) — not
    per engine — and donate the physical/pinned pools through updates."""
    donate = _donate_pool_args()
    quantum = jax.jit(
        _paged_quantum_impl,
        static_argnames=(
            "cfg", "ctx", "dtype", "spec", "quantum", "sampled", "truncated",
            "faulty",
        ),
        donate_argnums=(1, 2) if donate else (),
    )
    copyb = jax.jit(
        _copy_blocks_impl, static_argnames=("spec",),
        donate_argnums=(0,) if donate else (),
    )
    zerob = jax.jit(
        _zero_blocks_impl, static_argnames=("spec",),
        donate_argnums=(0,) if donate else (),
    )
    snap = jax.jit(_pinned_snapshot_impl, static_argnames=("spec",))
    restore = jax.jit(
        _pinned_restore_impl, static_argnames=("spec",),
        donate_argnums=(0,) if donate else (),
    )
    preset = jax.jit(
        _pinned_reset_impl, static_argnames=("spec",),
        donate_argnums=(0,) if donate else (),
    )
    return quantum, copyb, zerob, snap, restore, preset


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n, capped at ``cap`` — bounds the set of
    jit specializations (view widths, copy/zero batch sizes)."""
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


# ---------------------------------------------------------------- engine


class PagedServeEngine:
    """Paged continuous-batching engine: ``submit() / step() / drain()``.

    Same request semantics and (seed, rid, token index) sampling streams
    as :class:`repro.serve.engine.ServeEngine`, plus:

      * block-paged cache memory with copy-on-write sharing and a radix
        prefix cache (requests sharing a system prompt prefill once);
      * chunked prefill interleaved with decode inside one jitted quantum
        (no per-prompt-length compile; TTFT bounded under overload);
      * priority/deadline admission with starvation-free readmission and
        bounded priority preemption (:mod:`repro.serve.slo`);
      * graceful degradation under memory pressure: allocation falls back
        to radix LRU eviction, then to preempting the weakest resident
        (strict (priority, age) order, so the strongest request always
        makes progress).

    Mesh-native: pass ``ectx`` with a mesh (and ``param_axes``) and the
    physical block pool lives sharded by the same rule engine as the dense
    pool (block dim on the data axes, heads/channels on model).
    """

    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 pcfg: Optional[PagedConfig] = None, *, seed: int = 0,
                 ectx: Optional[ExecutionContext] = None, param_axes=None,
                 injector: Optional[FaultInjector] = None):
        for m in cfg.pattern:
            if not get_mixer(m).supports_decode:
                raise ValueError(
                    f"mixer '{m}' does not support decode; cannot serve "
                    f"pattern {cfg.pattern}"
                )
        if cfg.frontend or cfg.frontend_len:
            raise ValueError(
                "PagedServeEngine does not support modality-frontend "
                "configs; strip the frontend or use generate()"
            )
        self.cfg = cfg
        self.scfg = scfg
        self.pcfg = pcfg or PagedConfig()
        ctx = resolve_serve_context(scfg, ectx)
        self.ctx = ctx
        params = ctx.cast_compute(params)
        if ctx.mesh is not None and param_axes is not None:
            params = ctx.place(params, ctx.param_shardings(param_axes, params))
        self.params = params
        self._base_key = jax.random.PRNGKey(seed)

        S = scfg.n_slots
        page = self.pcfg.page_size
        self._pages_max = max(1, math.ceil(scfg.max_len / page))
        n_blocks = self.pcfg.n_blocks or S * self._pages_max + 1
        self.alloc = BlockAllocator(n_blocks)
        self.radix = (
            RadixPrefixCache(page, self.alloc) if self.pcfg.prefix_cache
            else None
        )

        # template cache -> static pool spec + physical pools
        with ctx.scope():
            template = _template_prefill(
                self.params, cfg=cfg, ctx=ctx, dtype=scfg.cache_dtype,
                max_len=scfg.max_len,
            )
        self.spec = build_pool_spec(cfg, template, page)
        t_leaves = jax.tree_util.tree_flatten(template)[0]

        def paged_shape(leaf, s):
            shp = list(leaf.shape)
            shp[s] = n_blocks
            shp[s + 1] = page
            return tuple(shp)

        self._phys = [
            jnp.zeros(paged_shape(t_leaves[i], self.spec.slot_axes[i]),
                      t_leaves[i].dtype)
            for i in self.spec.paged_idx
        ]
        self._pinned = [
            jnp.zeros(
                tuple(S if d == self.spec.slot_axes[i] else n
                      for d, n in enumerate(t_leaves[i].shape)),
                t_leaves[i].dtype,
            )
            for i in self.spec.pinned_idx
        ]
        self._shared = [jnp.array(t_leaves[i]) for i in self.spec.shared_idx]
        self._shardings = None
        self._mesh_ops = None
        if ctx.mesh is not None:
            shard_axes = _axes_leaves(lm.cache_shard_axes(cfg, template))
            from repro.distributed.sharding import tree_shardings

            def place(leaves, idx):
                ax = [shard_axes[i] for i in idx]
                sh = tree_shardings(ax, leaves, ctx.mesh, fsdp=ctx.fsdp,
                                    data_axes=ctx.data_axes)
                return jax.device_put(leaves, sh), sh

            self._phys, phys_sh = place(self._phys, self.spec.paged_idx)
            self._pinned, pin_sh = place(self._pinned, self.spec.pinned_idx)
            self._shared, shr_sh = place(self._shared, self.spec.shared_idx)
            self._shardings = (phys_sh, pin_sh, shr_sh)

        # host scheduling state
        self._table = np.zeros((S, self._pages_max), np.int32)
        self._t = np.zeros((S,), np.int64)  # tokens absorbed per slot
        self._p0 = np.zeros((S,), np.int64)  # original prompt length
        self._last = np.zeros((S,), np.int32)  # last sampled token
        self._feed: Dict[int, np.ndarray] = {}  # slot -> admission feed
        self.queue = SLOQueue()
        self.residents: Dict[int, Request] = {}  # slot -> request
        self._free_slots: List[int] = list(range(S))[::-1]
        self._requests: Dict[int, Request] = {}
        self._prio: Dict[int, int] = {}
        self._deadline: Dict[int, Optional[int]] = {}
        self._final: Dict[int, RequestResult] = {}  # terminal outcomes
        self._next_rid = 0
        self._tick = 0
        self.request_metrics: Dict[int, Dict[str, Any]] = {}
        # --- failure-domain state (DESIGN.md §13)
        self.injector = injector
        self._faulty = injector is not None and injector.poisons
        self._pending_quarantine: List[int] = []  # rids flagged this tick
        self.n_quarantined = 0
        self.n_retried = 0
        self.n_shed = 0
        self._straggler = StragglerMonitor()
        self._heartbeat = None
        if scfg.heartbeat_path is not None:
            self._heartbeat = Heartbeat(scfg.heartbeat_path)
            self._heartbeat.beat()

    # ------------------------------------------------------------- public
    @property
    def idle(self) -> bool:
        return not self.queue and not self.residents

    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int,
        temperature: Optional[float] = None,
        top_k: Optional[int] = None,
        stop_tokens: Sequence[int] = (),
        stream: Optional[Callable[[int, int, bool], None]] = None,
        priority: int = 0,
        deadline: Optional[int] = None,
    ) -> int:
        """Enqueue a request; returns its rid.  ``priority`` (higher wins)
        and ``deadline`` (scheduler tick) order admission — see
        :mod:`repro.serve.slo`."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len {self.scfg.max_len}"
            )
        need = math.ceil((prompt.size + max_new_tokens) / self.pcfg.page_size)
        if need > self.alloc.n_blocks - 1:
            raise ValueError(
                f"request needs {need} blocks but the pool holds "
                f"{self.alloc.n_blocks - 1}; raise PagedConfig.n_blocks"
            )
        sp = SamplingParams(
            max_new_tokens=int(max_new_tokens),
            temperature=self.scfg.temperature if temperature is None
            else float(temperature),
            top_k=self.scfg.top_k if top_k is None else int(top_k),
            stop_tokens=tuple(int(t) for t in stop_tokens),
        )
        rid = self._next_rid
        self._next_rid += 1
        self.request_metrics[rid] = {
            "submit_tick": self._tick, "first_token_tick": None,
            "done_tick": None, "prefix_cached_tokens": 0,
        }
        if deadline is not None and int(deadline) <= self._tick:
            # already expired at submission: structured abort, no residency
            self._final[rid] = RequestResult(
                rid, "deadline_exceeded", (),
                f"deadline {deadline} <= tick {self._tick} at submit",
            )
            return rid
        req = Request(rid=rid, prompt=prompt, params=sp, stream=stream,
                      deadline=None if deadline is None else int(deadline))
        self._requests[rid] = req
        self._prio[rid] = int(priority)
        self._deadline[rid] = deadline
        self.queue.push(rid, priority=priority, deadline=deadline)
        self._shed_overload()
        return rid

    def step(self) -> List[Event]:
        """One tick: SLO-ordered admissions (with bounded priority
        preemption), then one fused paged quantum that advances chunked
        prefills and decodes together."""
        self._tick += 1
        t0 = time.perf_counter()
        if self.injector is not None:
            slow = self.injector.slow_step_seconds(self._tick)
            if slow:
                time.sleep(slow)
        self._enforce_deadlines()
        events: List[Event] = []
        by_rid: Dict[int, Request] = {}
        try:
            self._admit(by_rid)
            if self.residents:
                self._quantum(events, by_rid)
        finally:
            self._dispatch_streams(events, by_rid)
            self._process_quarantine()
            self._prune_finished()
            self._straggler.record(self._tick, time.perf_counter() - t0)
            if self._heartbeat is not None:
                self._heartbeat.beat()
        return events

    # ------------------------------------------- lifecycle guards (§13)
    def _finalize(self, req: Request, status: str, detail: str = "") -> None:
        self._final[req.rid] = RequestResult(
            req.rid, status, tuple(req.tokens), detail
        )

    def _abort(self, rid: int, status: str, detail: str = "") -> bool:
        """Terminate a live (queued or resident) request with a structured
        status, releasing its slot's blocks (refcounted; radix-shared
        blocks stay pinned by the tree) if resident and clearing every
        piece of queue/priority/deadline bookkeeping.  False if rid is
        unknown or already terminal."""
        req = self._requests.get(rid)
        if req is None:
            return False
        if req.slot >= 0:
            self._release_slot(req.slot)
        else:
            self.queue.remove(rid)
        del self._requests[rid]
        self._prio.pop(rid, None)
        self._deadline.pop(rid, None)
        self.request_metrics[rid]["done_tick"] = self._tick
        self._finalize(req, status, detail)
        return True

    def cancel(self, rid: int) -> bool:
        """End-to-end cancellation: queued, readmitted, or mid-decode, the
        request's blocks are released (radix pins preserved) and it
        finalizes with partial tokens and ``status="cancelled"``."""
        return self._abort(rid, "cancelled")

    def _enforce_deadlines(self) -> None:
        expired = [
            rid for rid, req in self._requests.items()
            if req.deadline is not None and self._tick > req.deadline
        ]
        for rid in expired:
            dl = self._requests[rid].deadline
            self._abort(rid, "deadline_exceeded",
                        f"deadline tick {dl} < tick {self._tick}")

    def _shed_overload(self) -> None:
        """Past the overload threshold, reject the weakest queued arrival
        (lowest priority, latest deadline, newest) with status "shed".
        Readmitted requests are never shed — their partial decode is work
        worth preserving."""
        thr = self.scfg.overload_threshold
        if thr <= 0:
            return
        while len(self.queue) > thr:
            victim = self.queue.worst()
            if victim is None:
                break  # only readmits queued
            self._abort(victim, "shed",
                        f"queue depth {len(self.queue)} > {thr}")
            self.n_shed += 1

    def _process_quarantine(self) -> None:
        """Slots whose quantum logits went non-finite this tick: release
        the slot's blocks and replay from the last good token (the
        ``(seed, rid, token_index)`` key streams make the replay
        token-identical), or finalize ``status="failed"`` on strike-out /
        MoE (no continuation parity to replay through)."""
        pending, self._pending_quarantine = self._pending_quarantine, []
        for rid in pending:
            req = self._requests.get(rid)
            if req is None or req.slot < 0:
                continue  # finished before the poisoned step — moot
            req.quarantines += 1
            self.n_quarantined += 1
            if self.cfg.moe:
                self._abort(rid, "failed",
                            "non-finite logits; MoE cannot replay "
                            "(no continuation parity)")
            elif req.quarantines >= self.scfg.quarantine_strikes:
                self._abort(rid, "failed",
                            f"non-finite logits after "
                            f"{req.quarantines} quarantine strike(s)")
            else:
                self._evict_slot(req.slot)  # replay from last-good token

    def health(self) -> Dict[str, Any]:
        """Liveness/saturation surface for an external controller
        (DESIGN.md §13)."""
        return {
            "tick": self._tick,
            "queued": len(self.queue),
            "resident": len(self.residents),
            "finished": len(self._final),
            "free_blocks": self.alloc.n_free,
            "radix_nodes": 0 if self.radix is None else self.radix.n_nodes,
            "quarantined": self.n_quarantined,
            "retried": self.n_retried,
            "shed": self.n_shed,
            "stragglers": self._straggler.stragglers,
            "last_straggler": self._straggler.last_report,
            "heartbeat": self.scfg.heartbeat_path,
        }

    def evict(self, rid: int) -> bool:
        """Preempt a resident request (continuation semantics: it re-enters
        the readmit queue ahead of new arrivals and resumes from
        ``prompt + emitted``)."""
        if self.cfg.moe:
            raise ValueError(
                "eviction-with-continuation is unsupported for MoE "
                "configs: capacity-based token dropping breaks "
                "prefill/decode parity on readmission"
            )
        for slot, req in self.residents.items():
            if req.rid == rid:
                self._evict_slot(slot)
                return True
        return False

    def drain(self, max_steps: int = 100_000) -> Dict[int, np.ndarray]:
        """Step until queue and pool are empty; returns rid -> tokens.
        Raises :class:`DrainExhausted` (carrying partial results) if the
        budget runs out with requests still active."""
        steps = 0
        while not self.idle:
            self.step()
            steps += 1
            if steps > max_steps:
                active = sorted(
                    set(r.rid for r in self.residents.values())
                    | set(self.queue.rids())
                )
                partial = self.results()
                # release the unfinished residents' blocks and pinned
                # state BEFORE raising so an abandoning caller doesn't
                # leak the pool (radix refcounts stay with the tree;
                # flush_prefix() reclaims those).  Eviction readmits, so
                # the engine stays resumable.  MoE can't evict.
                if not self.cfg.moe:
                    for slot in list(self.residents):
                        self._evict_slot(slot)
                raise DrainExhausted(max_steps, partial, active)
        return self.results()

    def results(self) -> Dict[int, np.ndarray]:
        out = {
            rid: np.asarray(res.tokens, np.int32)
            for rid, res in self._final.items()
        }
        out.update({
            rid: np.asarray(req.tokens, np.int32)
            for rid, req in self._requests.items()
        })
        return out

    def pop_result(self, rid: int) -> np.ndarray:
        return np.asarray(self._final.pop(rid).tokens, np.int32)

    def result(self, rid: int) -> Optional[RequestResult]:
        """The structured terminal outcome of ``rid`` (None while live)."""
        return self._final.get(rid)

    def request_results(self) -> Dict[int, RequestResult]:
        """All terminal outcomes so far (rid -> :class:`RequestResult`)."""
        return dict(self._final)

    # ------------------------------------------------- prefix-cache hooks
    def flush_prefix(self) -> None:
        """Drop the whole radix tree (and zero any blocks it released)."""
        if self.radix is not None:
            self._zero_freed(self.radix.flush())

    def evict_prefix_node(self, rng) -> None:
        """Drop one random radix leaf — the parity harness's chaos hook."""
        if self.radix is not None:
            self._zero_freed(self.radix.evict_node(rng))

    def state_bytes(self) -> int:
        """Resident cache-pool footprint (blocks + pinned + shared)."""
        return int(sum(
            x.nbytes for x in self._phys + self._pinned + self._shared
        ))

    def check_clean(self) -> None:
        """Assert the pool invariants of an idle engine after
        ``flush_prefix()``: every block free with refcount 0, tables
        cleared, and all physical state zeroed (block 0 — the trash block
        — excepted, it absorbs padding writes by design)."""
        assert self.idle, "check_clean() requires an idle engine"
        assert self.alloc.n_free == self.alloc.n_blocks - 1, (
            f"leaked blocks: {self.alloc.n_blocks - 1 - self.alloc.n_free}"
        )
        assert not self.alloc.ref.any(), "nonzero refcounts on idle engine"
        assert not self._table.any(), "stale block-table entries"
        for j, i in enumerate(self.spec.paged_idx):
            s = self.spec.slot_axes[i]
            ph = np.asarray(jnp.moveaxis(self._phys[j], s, 0)[1:])
            assert not ph.any(), f"paged leaf {i} has non-zero freed blocks"
        for leaf in self._pinned:
            assert not np.asarray(leaf).any(), "pinned pool not zeroed"

    # ---------------------------------------------------------- admission
    def _strength(self, req: Request) -> Tuple[int, int]:
        """Total preemption order: priority first, then age (older rid
        wins).  Strict totality guarantees the strongest request always
        makes progress — no preemption livelock."""
        return (self._prio[req.rid], -req.rid)

    def _admit(self, by_rid: Dict[int, Request]) -> None:
        preempted = 0
        while True:
            cand = self.queue.peek()
            if cand is None:
                break
            rid, is_readmit = cand
            if not self._free_slots:
                # bounded priority preemption: a strictly stronger arrival
                # may displace the weakest resident (readmits never
                # preempt — they already ran once this residency cycle)
                if (is_readmit or not self.residents
                        or preempted >= self.pcfg.max_preemptions_per_step):
                    break
                cand_req = self._requests[rid]
                victim = min(self.residents,
                             key=lambda s: self._strength(self.residents[s]))
                if self._strength(self.residents[victim]) >= \
                        self._strength(cand_req):
                    break
                # commit to the candidate BEFORE evicting: the victim
                # lands in the readmit deque, and if we re-peeked it would
                # immediately reclaim the freed slot — preempting forever
                # while the stronger arrival starves
                self.queue.pop()
                self._evict_slot(victim)
                preempted += 1
                self._admit_into(self._free_slots.pop(), rid, by_rid)
                continue
            self.queue.pop()
            self._admit_into(self._free_slots.pop(), rid, by_rid)

    def _admit_into(self, slot: int, rid: int,
                    by_rid: Dict[int, Request]) -> None:
        req = self._requests[rid]
        self.residents[slot] = req
        req.slot = slot
        by_rid[rid] = req
        feed = req.resume_prompt
        self._feed[slot] = feed
        self._p0[slot] = len(req.prompt)
        t0 = 0
        if self.radix is not None and len(feed) > 1:
            depth, blocks, snap = self.radix.match(feed)
            if depth:
                for b in blocks:
                    self.alloc.incref(b)
                self._table[slot, :len(blocks)] = blocks
                _, _, _, _, restore, _ = self._ops()
                with self.ctx.scope():
                    self._pinned = restore(
                        self._pinned, jnp.asarray(slot, jnp.int32),
                        snap, spec=self.spec,
                    )
                t0 = depth
                self.request_metrics[req.rid]["prefix_cached_tokens"] = \
                    max(self.request_metrics[req.rid]
                        ["prefix_cached_tokens"], depth)
        self._t[slot] = t0

    # ------------------------------------------------------ block capacity
    def _zero_freed(self, blocks: List[int]) -> None:
        if not blocks:
            return
        k = _pow2_bucket(len(blocks), max(self.alloc.n_blocks, 1))
        ids = np.zeros((k,), np.int32)
        ids[: len(blocks)] = blocks
        _, _, zerob, _, _, _ = self._ops()
        with self.ctx.scope():
            self._phys = zerob(self._phys, jnp.asarray(ids), spec=self.spec)

    def _alloc_block(self, slot: int) -> Optional[int]:
        """Allocate one block for ``slot``, escalating under pressure:
        free list -> radix LRU eviction -> preempt a strictly weaker
        resident.  Returns None when ``slot`` itself is the weakest — it
        then stalls this quantum (adv = 0) instead of thrashing."""
        if self.injector is not None and self.injector.alloc_fails(
                self._tick, slot):
            return None  # injected exhaustion: stall, retry next tick
        while True:
            b = self.alloc.alloc()
            if b is not None:
                return b
            if self.radix is not None and self.radix.n_nodes:
                self._zero_freed(self.radix.evict_lru(1))
                continue
            me = self.residents.get(slot)
            victims = [
                s for s, r in self.residents.items()
                if s != slot and (me is None
                                  or self._strength(r) < self._strength(me))
            ]
            if not victims:
                return None
            v = min(victims, key=lambda s: self._strength(self.residents[s]))
            self._evict_slot(v)

    def _ensure_writable(self, slot: int, t: int, adv: int) -> bool:
        """Make every page the next ``adv`` tokens touch privately
        writable: allocate unmapped pages (they arrive zeroed — I3) and
        copy-on-write any block shared with the radix tree or a fork."""
        page = self.pcfg.page_size
        first, last = t // page, (t + adv - 1) // page
        copies: List[Tuple[int, int]] = []
        for pg in range(first, last + 1):
            bid = int(self._table[slot, pg])
            if bid == 0:
                nb = self._alloc_block(slot)
                if nb is None:
                    return False
                self._table[slot, pg] = nb
            elif self.alloc.ref[bid] > 1:
                nb = self._alloc_block(slot)
                if nb is None:
                    return False
                copies.append((bid, nb))
                self.alloc.decref(bid)
                self._table[slot, pg] = nb
        if copies:
            k = _pow2_bucket(len(copies), max(self.alloc.n_blocks, 1))
            src = np.zeros((k,), np.int32)
            dst = np.zeros((k,), np.int32)
            src[: len(copies)] = [c[0] for c in copies]
            dst[: len(copies)] = [c[1] for c in copies]
            _, copyb, _, _, _, _ = self._ops()
            with self.ctx.scope():
                self._phys = copyb(
                    self._phys, jnp.asarray(src), jnp.asarray(dst),
                    spec=self.spec,
                )
        return True

    # ------------------------------------------------------------ quantum
    def _plan_adv(self, slot: int, req: Request) -> int:
        """Tokens this slot may absorb this quantum: the decode quantum,
        clipped to the next page boundary while feeding the prompt (so
        radix snapshots land page-aligned) and to the request's horizon."""
        t = int(self._t[slot])
        feed = self._feed[slot]
        q = self.scfg.decode_quantum
        page = self.pcfg.page_size
        a = q
        if self.radix is not None and t < len(feed):
            pb = (t // page + 1) * page
            if pb <= len(feed):
                a = min(a, pb - t)
        t_max = int(self._p0[slot]) + req.params.max_new_tokens - 1
        return max(1, min(a, t_max - t, self.scfg.max_len - t))

    def _quantum(self, events: List[Event], by_rid: Dict[int, Request]) -> None:
        S = self.scfg.n_slots
        Q = self.scfg.decode_quantum
        page = self.pcfg.page_size
        adv = np.zeros((S,), np.int32)
        for slot, req in list(self.residents.items()):
            adv[slot] = self._plan_adv(slot, req)
        # capacity: strongest-first so preemption cascades deterministically
        order = sorted(self.residents,
                       key=lambda s: self._strength(self.residents[s]),
                       reverse=True)
        for slot in order:
            if slot not in self.residents:  # preempted by a stronger slot
                continue
            if not self._ensure_writable(slot, int(self._t[slot]),
                                         int(adv[slot])):
                adv[slot] = 0  # stalled this quantum; retried next tick
        active = np.zeros((S,), bool)
        m = np.zeros((S,), np.int32)
        F = np.zeros((S, Q), np.int32)
        temps = np.zeros((S,), np.float32)
        topks = np.zeros((S,), np.int32)
        rids = np.zeros((S,), np.int32)
        for slot, req in self.residents.items():
            if adv[slot] == 0:
                continue
            active[slot] = True
            t = int(self._t[slot])
            feed = self._feed[slot]
            mm = max(0, min(len(feed) - t, int(adv[slot])))
            m[slot] = mm
            if mm:
                F[slot, :mm] = feed[t:t + mm]
            temps[slot] = req.params.temperature
            topks[slot] = req.params.top_k
            rids[slot] = req.rid
        if not active.any():
            return
        feed0 = np.where(m > 0, F[:, 0], self._last).astype(np.int32)
        feed_next = np.zeros((Q, S), np.int32)
        feed_next[: Q - 1] = F[:, 1:].T
        poison = np.zeros((Q, S), np.float32)
        if self._faulty:
            for slot, req in self.residents.items():
                if not active[slot]:
                    continue
                t = int(self._t[slot])
                p0s = int(self._p0[slot])
                for q in range(int(adv[slot])):
                    count = t + q + 1 - p0s  # token index sampled at step q
                    if count >= req.n_emitted and count >= 0:
                        # only NEW emissions are poison targets: refeed
                        # steps re-derive already-kept tokens from the
                        # feed, so poisoning them couldn't change outputs
                        poison[q, slot] = self.injector.poison_value(
                            req.rid, count, req.quarantines
                        )
        covered = int(max(
            (math.ceil((int(self._t[s]) + int(adv[s])) / page)
             for s in self.residents if adv[s] > 0),
            default=1,
        ))
        pv = _pow2_bucket(max(covered, 1), self._pages_max)
        table = jnp.asarray(self._table[:, :pv])
        quantum, _, _, _, _, _ = self._ops()
        attempt = [0]

        def dispatch():
            a = attempt[0]
            attempt[0] += 1
            if self.injector is not None:
                # raises BEFORE the jitted call dispatches: a failed
                # attempt never consumes the donated pool buffers
                self.injector.check_step(self._tick, a)
            with self.ctx.scope():
                return quantum(
                    self.params, self._phys, self._pinned, self._shared,
                    table,
                    jnp.asarray(feed0), jnp.asarray(feed_next),
                    jnp.asarray(m), jnp.asarray(adv),
                    jnp.asarray(self._t, jnp.int32),
                    jnp.asarray(self._p0, jnp.int32),
                    jnp.asarray(active), jnp.asarray(temps),
                    jnp.asarray(topks),
                    jnp.asarray(rids), self._base_key,
                    jnp.asarray(poison),
                    cfg=self.cfg, ctx=self.ctx, dtype=self.scfg.cache_dtype,
                    spec=self.spec, quantum=Q,
                    sampled=bool((temps[active] > 0.0).any()),
                    truncated=bool((topks[active] > 0).any()),
                    faulty=self._faulty,
                )

        toks, emits, finite, self._phys, self._pinned = retry(
            dispatch, attempts=self.scfg.step_retry_attempts,
            base_delay=self.scfg.step_retry_base_delay,
            exceptions=(TransientStepError,),
        )
        self.n_retried += attempt[0] - 1
        toks = np.asarray(toks)
        emits = np.asarray(emits)
        finite = np.asarray(finite)
        for slot in sorted(list(self.residents)):
            req = self.residents[slot]
            if not active[slot]:
                continue
            by_rid[req.rid] = req
            a = int(adv[slot])
            t0 = int(self._t[slot])
            p0 = int(self._p0[slot])
            col = finite[:a, slot]
            bad = None if col.all() else int(np.argmax(~col))
            done = False
            for q in range(a if bad is None else bad):
                if not emits[q, slot]:
                    continue
                count = t0 + q + 1 - p0
                if count < req.n_emitted:
                    continue  # re-derived during a continuation refeed
                tok = int(toks[q, slot])
                req.tokens.append(tok)
                met = self.request_metrics[req.rid]
                if met["first_token_tick"] is None:
                    met["first_token_tick"] = self._tick
                done = req.finished(tok)
                events.append(Event(req.rid, tok, done))
                if done:
                    break
            if done:
                self._finish_slot(slot)
                continue
            if bad is not None:
                # non-finite logits at step ``bad``: tokens before it are
                # kept, everything after is garbage.  The slot's cursors
                # are left as-is — _process_quarantine releases the slot
                # (or fails the request) right after this loop.
                self._pending_quarantine.append(req.rid)
                continue
            self._t[slot] = t0 + a
            if emits[a - 1, slot]:
                self._last[slot] = int(toks[a - 1, slot])
            self._maybe_insert_prefix(slot)

    def _maybe_insert_prefix(self, slot: int) -> None:
        """After a quantum ending exactly at a page boundary inside the
        prompt feed, record the prefix in the radix tree with a pinned
        snapshot taken at that boundary."""
        if self.radix is None:
            return
        t = int(self._t[slot])
        page = self.pcfg.page_size
        feed = self._feed[slot]
        if t == 0 or t % page != 0 or t > len(feed):
            return
        blocks = [int(b) for b in self._table[slot, : t // page]]
        _, _, _, snap_fn, _, _ = self._ops()
        with self.ctx.scope():
            snap = snap_fn(self._pinned, jnp.asarray(slot, jnp.int32),
                           spec=self.spec)
        self.radix.insert(feed[:t], blocks, snap)

    # ------------------------------------------------------------ release
    def _release_slot(self, slot: int) -> None:
        freed = []
        for pg in range(self._pages_max):
            bid = int(self._table[slot, pg])
            if bid and self.alloc.decref(bid):
                freed.append(bid)
        self._table[slot] = 0
        self._zero_freed(freed)
        _, _, _, _, _, preset = self._ops()
        with self.ctx.scope():
            self._pinned = preset(self._pinned, jnp.asarray(slot, jnp.int32),
                                  spec=self.spec)
        req = self.residents.pop(slot)
        req.slot = -1
        self._feed.pop(slot, None)
        self._t[slot] = 0
        self._p0[slot] = 0
        self._last[slot] = 0
        self._free_slots.append(slot)

    def _finish_slot(self, slot: int) -> None:
        rid = self.residents[slot].rid
        self.request_metrics[rid]["done_tick"] = self._tick
        self._release_slot(slot)

    def _evict_slot(self, slot: int) -> None:
        req = self.residents[slot]
        self._release_slot(slot)
        req.evictions += 1
        self.queue.push_readmit(req.rid)

    # ------------------------------------------------------- bookkeeping
    def _dispatch_streams(self, events: List[Event], by_rid) -> None:
        for ev in events:
            req = by_rid.get(ev.rid)
            if req is not None and req.stream is not None:
                req.stream(ev.rid, ev.token, ev.done)

    def _prune_finished(self) -> None:
        live = set(self.queue.rids())
        live |= {r.rid for r in self.residents.values()}
        for rid in [r for r in self._requests if r not in live]:
            req = self._requests.pop(rid)
            self._finalize(req, "completed")
            self._prio.pop(rid, None)
            self._deadline.pop(rid, None)

    # ---------------------------------------------------- jitted-op access
    def _ops(self):
        """(quantum, copy, zero, snapshot, restore, pinned_reset) —
        process-shared for meshless engines; mesh engines wrap each op with
        sharding constraints pinning the pools to the rule-derived layout
        (same pattern as the dense engine's ``_pool_ops``)."""
        if self.ctx.mesh is None:
            return _jitted_paged_ops()
        if self._mesh_ops is None:
            phys_sh, pin_sh, shr_sh = self._shardings

            def cphys(leaves):
                return [
                    jax.lax.with_sharding_constraint(x, s)
                    for x, s in zip(leaves, phys_sh)
                ]

            def cpin(leaves):
                return [
                    jax.lax.with_sharding_constraint(x, s)
                    for x, s in zip(leaves, pin_sh)
                ]

            def quantum_impl(params, phys, pinned, shared, table, feed0,
                             feed_next, m, adv, t0, p0, active, temps,
                             topks, rids, base_key, poison, *, cfg, ctx,
                             dtype, spec, quantum, sampled, truncated,
                             faulty=False):
                toks, emits, finite, ph, pi = _paged_quantum_impl(
                    params, cphys(phys), cpin(pinned), shared, table,
                    feed0, feed_next, m, adv, t0, p0, active, temps,
                    topks, rids, base_key, poison, cfg=cfg, ctx=ctx,
                    dtype=dtype, spec=spec, quantum=quantum,
                    sampled=sampled, truncated=truncated, faulty=faulty,
                )
                return toks, emits, finite, cphys(ph), cpin(pi)

            def copy_impl(phys, src, dst, *, spec):
                return cphys(_copy_blocks_impl(cphys(phys), src, dst,
                                               spec=spec))

            def zero_impl(phys, blocks, *, spec):
                return cphys(_zero_blocks_impl(cphys(phys), blocks,
                                               spec=spec))

            def restore_impl(pinned, slot, snap, *, spec):
                return cpin(_pinned_restore_impl(cpin(pinned), slot, snap,
                                                 spec=spec))

            def preset_impl(pinned, slot, *, spec):
                return cpin(_pinned_reset_impl(cpin(pinned), slot,
                                               spec=spec))

            donate = _donate_pool_args()
            self._mesh_ops = (
                jax.jit(
                    quantum_impl,
                    static_argnames=(
                        "cfg", "ctx", "dtype", "spec", "quantum",
                        "sampled", "truncated", "faulty",
                    ),
                    donate_argnums=(1, 2) if donate else (),
                ),
                jax.jit(copy_impl, static_argnames=("spec",),
                        donate_argnums=(0,) if donate else ()),
                jax.jit(zero_impl, static_argnames=("spec",),
                        donate_argnums=(0,) if donate else ()),
                jax.jit(_pinned_snapshot_impl, static_argnames=("spec",)),
                jax.jit(restore_impl, static_argnames=("spec",),
                        donate_argnums=(0,) if donate else ()),
                jax.jit(preset_impl, static_argnames=("spec",),
                        donate_argnums=(0,) if donate else ()),
            )
        return self._mesh_ops
