"""Byte-level tokenizer (vocab 256 + BOS/EOS/PAD). A GPT2-BPE vocabulary is
not shippable offline; byte-level is lossless and matches the synthetic &
example corpora in-repo.  Vocab ids: bytes 0..255, BOS=256, EOS=257, PAD=258.
"""
from __future__ import annotations

from typing import List

import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB_SIZE = 259


def encode(text: str, add_bos: bool = True, add_eos: bool = False) -> np.ndarray:
    ids = list(text.encode("utf-8"))
    if add_bos:
        ids = [BOS] + ids
    if add_eos:
        ids = ids + [EOS]
    return np.asarray(ids, dtype=np.int32)


def decode(ids) -> str:
    return bytes(int(i) for i in np.asarray(ids) if int(i) < 256).decode(
        "utf-8", errors="replace"
    )
