"""Deterministic, resumable, host-sharded LM data pipeline.

A token corpus (np.memmap on disk or in-memory array) is read as
next-token-prediction windows.  Each host reads only its shard of the
global batch (host h gets rows ``[h·B/H, (h+1)·B/H)``); the loader's state
is a single integer cursor saved inside the checkpoint → bit-exact resume
after preemption/restart, including on a *different* host count (elastic:
the cursor is in units of global steps, not host rows).

A background prefetch thread keeps ``prefetch`` batches ready so host input
never blocks the device step (compute/IO overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class TokenStream:
    def __init__(
        self,
        tokens: np.ndarray,  # (N,) int32 corpus (or np.memmap)
        *,
        global_batch: int,
        seq_len: int,
        host_id: int = 0,
        n_hosts: int = 1,
        cursor: int = 0,
        seed: int = 0,
        shuffle_windows: bool = True,
    ):
        assert global_batch % n_hosts == 0
        self.tokens = tokens
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.cursor = cursor
        self.seed = seed
        self.shuffle = shuffle_windows
        self.n_windows = (len(tokens) - 1) // seq_len
        if self.n_windows < global_batch:
            raise ValueError("corpus too small for one global batch")

    # ------------------------------------------------------------- state
    def state(self) -> Dict[str, int]:
        return {"cursor": int(self.cursor), "seed": int(self.seed)}

    def restore(self, state: Dict[str, int]) -> None:
        self.cursor = int(state["cursor"])
        self.seed = int(state["seed"])

    # -------------------------------------------------------------- next
    def _window_ids(self, step: int) -> np.ndarray:
        """Global window permutation for this epoch, deterministic in
        (seed, epoch)."""
        per_step = self.global_batch
        steps_per_epoch = self.n_windows // per_step
        epoch = step // steps_per_epoch
        within = step % steps_per_epoch
        rng = np.random.default_rng(self.seed * 1000003 + epoch)
        perm = (
            rng.permutation(self.n_windows)
            if self.shuffle
            else np.arange(self.n_windows)
        )
        sel = perm[within * per_step : (within + 1) * per_step]
        lo = self.host_id * (per_step // self.n_hosts)
        hi = lo + per_step // self.n_hosts
        return sel[lo:hi]

    def next_batch(self) -> Dict[str, np.ndarray]:
        ids = self._window_ids(self.cursor)
        L = self.seq_len
        tok = np.stack([self.tokens[i * L : i * L + L] for i in ids]).astype(np.int32)
        lab = np.stack(
            [self.tokens[i * L + 1 : i * L + L + 1] for i in ids]
        ).astype(np.int32)
        self.cursor += 1
        return {"tokens": tok, "labels": lab}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


class Prefetcher:
    """Background-thread prefetch of `depth` batches."""

    def __init__(self, stream: TokenStream, depth: int = 2):
        self.stream = stream
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.stream.next_batch()
            item = (batch, self.stream.state())
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.5)
                    break
                except queue.Full:
                    continue

    def next(self):
        batch, state = self._q.get()
        # state as of the *consumed* batch — checkpoint this (not the
        # stream's own cursor, which has run ahead by the prefetch depth)
        self.consumed_state = state
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
