"""Mechanistic-design synthetic tasks (paper §4.1, Table 4.1, App. A.1).

  Associative recall   a, 1, b, e, 3, f, b → e
  Majority             a, g, g, g, e, f, g → g
  Counting             a, b, b, b, a, c, b → 4
  ICL of functions     x₀, f(x₀), …, xₙ → f(xₙ)     (linear f, tokenized)
  Arithmetic           1,3,5, +, 6,8,3 → 8,1,8      (Dₙ-digit addition)

Each generator returns (tokens, labels) int32 arrays with labels = IGNORE
except at supervised positions, exactly the autoregressive masking the
paper uses (App. C.1 masks "the first 2·Dₙ−1 elements" for addition).

Difficulty knobs follow App. A.1: sequence length ∈ {1k … 131k} and
vocabulary size ∈ {10, 20, 30, 40}.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

IGNORE = -1

# token-space layout for symbolic tasks: keys/values share [0, vocab);
# special query marker = vocab; separator = vocab + 1.


def associative_recall(
    rng: np.random.Generator, *, n: int, seq_len: int, vocab: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Key-value pairs concatenated; final key queries its value.
    tokens: (n, seq_len), labels: (n, seq_len) IGNORE except last position."""
    assert seq_len % 2 == 0
    n_pairs = (seq_len - 2) // 2
    keys = rng.integers(0, vocab // 2, size=(n, n_pairs))
    # per-sequence random dictionary: value of key k drawn once per sequence
    dict_vals = rng.integers(vocab // 2, vocab, size=(n, vocab // 2))
    vals = np.take_along_axis(dict_vals, keys, axis=1)
    body = np.empty((n, 2 * n_pairs), dtype=np.int64)
    body[:, 0::2] = keys
    body[:, 1::2] = vals
    q_idx = rng.integers(0, n_pairs, size=n)
    q_key = keys[np.arange(n), q_idx]
    q_val = vals[np.arange(n), q_idx]
    tokens = np.concatenate(
        [body, q_key[:, None], q_val[:, None]], axis=1
    ).astype(np.int32)
    labels = np.full_like(tokens, IGNORE)
    labels[:, -2] = q_val  # predict the value right after the queried key
    return tokens, labels


def majority(
    rng: np.random.Generator, *, n: int, seq_len: int, vocab: int
) -> Tuple[np.ndarray, np.ndarray]:
    toks = rng.integers(0, vocab, size=(n, seq_len - 1))
    # bias one symbol to be the clear majority
    maj = rng.integers(0, vocab, size=n)
    m = rng.random((n, seq_len - 1)) < 0.5
    toks = np.where(m, maj[:, None], toks)
    counts = np.apply_along_axis(np.bincount, 1, toks, minlength=vocab)
    answer = counts.argmax(axis=1)
    tokens = np.concatenate([toks, answer[:, None]], axis=1).astype(np.int32)
    labels = np.full_like(tokens, IGNORE)
    labels[:, -2] = answer
    return tokens, labels


def counting(
    rng: np.random.Generator, *, n: int, seq_len: int, vocab: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Count occurrences of the final symbol (count capped at vocab)."""
    toks = rng.integers(0, vocab, size=(n, seq_len - 1))
    target = toks[:, -1]
    counts = (toks == target[:, None]).sum(axis=1)
    counts = np.minimum(counts, vocab - 1)
    tokens = np.concatenate([toks, counts[:, None]], axis=1).astype(np.int32)
    labels = np.full_like(tokens, IGNORE)
    labels[:, -2] = counts
    return tokens, labels


def icl_linear_functions(
    rng: np.random.Generator, *, n: int, n_points: int, vocab: int
) -> Tuple[np.ndarray, np.ndarray]:
    """x₀, w·x₀ mod V, x₁, … — in-context regression of a per-sequence
    linear map over Z_V (tokenized analogue of the paper's real-valued
    task)."""
    w = rng.integers(1, vocab, size=(n, 1))
    xs = rng.integers(0, vocab, size=(n, n_points))
    ys = (w * xs) % vocab
    seq = np.empty((n, 2 * n_points), dtype=np.int64)
    seq[:, 0::2] = xs
    seq[:, 1::2] = ys
    tokens = seq.astype(np.int32)
    labels = np.full_like(tokens, IGNORE)
    labels[:, -2] = ys[:, -1]
    return tokens, labels


def addition(
    rng: np.random.Generator, *, n: int, n_digits: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Dₙ-digit addition (App. C.1): digits of a, digits of b, then the
    (Dₙ+1)-digit sum; loss masked on the first 2Dₙ−1 positions."""
    base = 10
    a = rng.integers(0, base ** n_digits, size=n)
    b = rng.integers(0, base ** n_digits, size=n)
    s = a + b

    def digits(x, k):
        return np.stack(
            [(x // base ** (k - 1 - i)) % base for i in range(k)], axis=1
        )

    tokens = np.concatenate(
        [digits(a, n_digits), digits(b, n_digits), digits(s, n_digits + 1)],
        axis=1,
    ).astype(np.int32)
    labels = np.full_like(tokens, IGNORE)
    # supervise the sum digits: predict position t+1 from t
    L = tokens.shape[1]
    labels[:, 2 * n_digits - 1 : L - 1] = tokens[:, 2 * n_digits : L]
    return tokens, labels


TASKS = {
    "associative_recall": associative_recall,
    "majority": majority,
    "counting": counting,
    "icl_functions": icl_linear_functions,
    "addition": addition,
}


def eval_accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    """Accuracy over supervised positions (labels != IGNORE)."""
    mask = labels != IGNORE
    pred = logits.argmax(-1)
    return float((pred[mask] == labels[mask]).mean())
