"""repro: a production-grade JAX framework reproducing *Hyena Hierarchy*
(Poli et al., ICML 2023) with multi-pod distribution, Pallas TPU kernels,
and a composable model zoo.
"""

__version__ = "1.0.0"

from repro.common.param import Ax, split_params, merge_params  # noqa: F401
