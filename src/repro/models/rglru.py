"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = σ(W_a x_t + b_a)                 recurrence gate
    i_t = σ(W_x x_t + b_x)                 input gate
    a_t = exp(-c · softplus(Λ) · r_t)      c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the linear recurrence
(h_t = a_t h_{t-1} + b_t is associative); decode is the O(1) step.  The full
Griffin recurrent *block* wraps the RG-LRU with a width-4 temporal conv and
a GeLU gate branch, as in the paper's Figure 2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.distributed.ctx import shard
from repro.core.fftconv import short_causal_conv
from repro.models.layers import dense, init_dense

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int = 0  # lru width; 0 -> d_model
    conv_width: int = 4

    @property
    def width(self) -> int:
        return self.d_rnn or self.d_model


def init_rglru(key, cfg: RGLRUConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    W = cfg.width
    # Λ init so that a^c spans roughly (0.9, 0.999) as in the paper
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "in_x": init_dense(ks[1], cfg.d_model, W, ("embed", "rnn_hidden")),
        "in_gate": init_dense(ks[2], cfg.d_model, W, ("embed", "rnn_hidden")),
        "conv_w": Ax(
            jax.random.normal(ks[3], (W, cfg.conv_width), jnp.float32)
            / jnp.sqrt(cfg.conv_width),
            ("rnn_hidden", None),
        ),
        "gate_a": init_dense(ks[4], W, W, ("rnn_hidden", "rnn_hidden")),
        "gate_x": init_dense(ks[5], W, W, ("rnn_hidden", "rnn_hidden")),
        "lambda": Ax(lam, ("rnn_hidden",)),
        "out": init_dense(jax.random.fold_in(key, 7), W, cfg.d_model, ("rnn_hidden", "embed")),
    }


def _rglru_core(params, x: jax.Array, h0=None):
    """x: (B, L, W) conv output -> (y, h_last). fp32 recurrence."""
    B, L, W = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(params["gate_a"], xf))
    i = jax.nn.sigmoid(dense(params["gate_x"], xf))
    log_a = -_C * jax.nn.softplus(params["lambda"])[None, None, :] * r  # (B,L,W)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    return Bc, Bc[:, -1]


def apply_rglru(params, cfg: RGLRUConfig, x: jax.Array, *, pos_offset: int = 0):
    """Griffin recurrent block: conv + RG-LRU path, GeLU gate branch."""
    B, L, D = x.shape
    u = dense(params["in_x"], x)
    u = shard(u, "data", None, "model")
    g = jax.nn.gelu(dense(params["in_gate"], x))
    u = short_causal_conv(u, params["conv_w"])
    y, _ = _rglru_core(params, u)
    y = (y.astype(x.dtype)) * g
    return dense(params["out"], y)


def rglru_prefill(
    params, cfg: RGLRUConfig, x: jax.Array, max_len: int, dtype=jnp.bfloat16,
    *, pos_offset: int = 0,
):
    B, L, D = x.shape
    u_raw = dense(params["in_x"], x)
    g = jax.nn.gelu(dense(params["in_gate"], x))
    u = short_causal_conv(u_raw, params["conv_w"])
    y, h_last = _rglru_core(params, u)
    out = dense(params["out"], (y.astype(x.dtype)) * g)
    K = cfg.conv_width
    n = min(L, K - 1)
    hist = jnp.flip(u_raw[:, L - n :], axis=1).astype(dtype)
    hist = jnp.pad(hist, ((0, 0), (0, K - 1 - n), (0, 0)))
    cache = {"conv": hist, "h": h_last, "t": jnp.full((B,), L, jnp.int32)}
    return out, cache


def init_rglru_cache(cfg: RGLRUConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    W = cfg.width
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W), dtype),
        "h": jnp.zeros((batch, W), jnp.float32),
        "t": jnp.zeros((batch,), jnp.int32),
    }


def rglru_decode_step(params, cfg: RGLRUConfig, x_t: jax.Array, cache):
    B, D = x_t.shape
    u = dense(params["in_x"], x_t)
    g = jax.nn.gelu(dense(params["in_gate"], x_t))
    w = params["conv_w"]
    hist = cache["conv"]
    acc = u.astype(jnp.float32) * w[:, 0][None]
    for k in range(1, cfg.conv_width):
        acc = acc + hist[:, k - 1].astype(jnp.float32) * w[:, k][None]
    new_conv = jnp.concatenate(
        [u[:, None, :].astype(hist.dtype), hist[:, : cfg.conv_width - 2]], axis=1
    )
    uf = acc  # fp32 (B, W)
    r = jax.nn.sigmoid(dense(params["gate_a"], uf))
    i = jax.nn.sigmoid(dense(params["gate_x"], uf))
    log_a = -_C * jax.nn.softplus(params["lambda"])[None, :] * r
    a = jnp.exp(log_a)
    h = a * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    y = (h.astype(x_t.dtype)) * g
    y = dense(params["out"], y)
    return y, {"conv": new_conv, "h": h, "t": cache["t"] + 1}


# ----------------------------------------------------------- registration

from repro.models.mixer_api import ApplyContext, TokenMixer, register_mixer  # noqa: E402


@register_mixer
class RGLRUMixer(TokenMixer):
    """Griffin recurrent block: conv + RG-LRU path with a GeLU gate branch."""

    name = "rglru"
    attention_free = True
    subquadratic = True

    def make_config(self, cfg) -> RGLRUConfig:
        return RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.rnn_width)

    def init(self, key, mc):
        return init_rglru(key, mc)

    def apply(self, params, mc, h, ctx: ApplyContext):
        return apply_rglru(params, mc, h, pos_offset=ctx.pos_offset)

    def init_cache(self, mc, batch, max_len, dtype):
        return init_rglru_cache(mc, batch, max_len, dtype)

    def prefill(self, params, mc, h, max_len, dtype, ctx: ApplyContext):
        return rglru_prefill(
            params, mc, h, max_len, dtype, pos_offset=ctx.pos_offset
        )

    def decode_step(self, params, mc, h_t, cache):
        return rglru_decode_step(params, mc, h_t, cache)

    def cache_shard_axes(self, mc) -> dict:
        # RG-LRU recurrence and conv history are elementwise over the RNN
        # width — shard it over model, replicate slots and cursors
        return {
            "conv": ("cache_slots", None, "rnn_hidden"),
            "h": ("cache_slots", "rnn_hidden"),
        }

    def state_bytes(self, cfg, max_len: int) -> int:
        mc = self.make_config(cfg)
        W = mc.width
        conv = (mc.conv_width - 1) * W * 2  # bf16 conv history
        return conv + W * 4 + 4  # fp32 hidden state + int32 cursor

    def flops(self, cfg, L: int) -> float:
        mc = self.make_config(cfg)
        D, W = mc.d_model, mc.width
        proj = 2 * D * W + W * D  # in_x, in_gate, out
        gates = 2 * W * W  # gate_a, gate_x
        conv = W * mc.conv_width
        scan = 4 * W  # elementwise recurrence
        return 2.0 * L * (proj + gates + conv + scan)
