"""Hyena SE/MR/LI multi-hybrid operator variants (StripedHyena-2 style,
arXiv:2503.01868), registered as first-class token mixers.

The multi-hybrid result: interleaving *short explicit* (SE), *medium
regularized* (MR), and *long implicit* (LI) hyena layers beats any single
operator at equal compute — the short layers carry local token mixing at
FIR cost, the medium layers carry syntax-scale context from a fixed-support
implicit filter, and only the (fewer) long layers pay for the full-length
FFT conv.  All three share the Hyena projection/gating recurrence
(``repro.core.operator``); they differ only in the filter parameterization
and therefore in decode-state shape:

  ``hyena_se``  explicit taps ``(order, D, se_len)`` as *parameters*;
                train/prefill is a depthwise FIR (shifted adds — stays
                sequence-sharded under cp with SPMD halo exchange, no
                channel all-to-all); decode is a stacked short-conv dot
                over an ``(se_len-1)``-deep rolling operand window.
  ``hyena_mr``  the implicit filter FFN evaluated on a FIXED
                ``support``-point grid (taps are length-invariant, unlike
                LI's length-L grid), zero-padded to L for the full-sequence
                conv — which routes through the registry backend from
                ``ExecutionContext.conv_backend_for(L)`` (blockfft_overlap
                / fft_sp under cp), gate fused; decode is the same stacked
                window dot with ``support-1`` depth.
  ``hyena_li``  the existing full-length implicit operator
                (:class:`repro.models.hyena.HyenaMixer`) under its
                multi-hybrid name.

SE/MR decode state is O(window), not O(max_len): their cache windows are
bounded rolling buffers (newest-first, zero-padded — decode needs no
cursor masking), so both are *pinned* leaves under the paged allocator
(``cache_page_axes() == {}``; paging a bounded window buys nothing).
Multi-hybrid pattern rules (which stripings are coherent) are validated at
config registration in ``repro.configs.base``.  DESIGN.md §14.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.core import filters as F
from repro.core.conv_api import get_conv_backend
from repro.core.fftconv import short_causal_conv
from repro.core.operator import _fallback_decode_taps
from repro.distributed.ctx import shard
from repro.models.hyena import HyenaMixer
from repro.models.mixer_api import (
    DEFAULT_CONTEXT,
    ApplyContext,
    TokenMixer,
    register_mixer,
)


# --------------------------------------------------------------- configs

@dataclasses.dataclass(frozen=True)
class HyenaSEConfig:
    d_model: int
    order: int = 2
    se_len: int = 8  # explicit FIR taps per order (the SE filter support)
    short_filter_len: int = 3
    use_bias: bool = True


@dataclasses.dataclass(frozen=True)
class HyenaMRConfig:
    d_model: int
    order: int = 2
    support: int = 128  # fixed tap-grid length M (filters are zero past M)
    short_filter_len: int = 3
    filter: F.FilterConfig = None  # type: ignore[assignment]
    use_bias: bool = True

    def __post_init__(self):
        if self.filter is None:
            object.__setattr__(
                self,
                "filter",
                F.FilterConfig(d_model=self.d_model, order=self.order),
            )


# ------------------------------------------------- shared projection path

def _init_projection(key, d_model: int, order: int, short_filter_len: int,
                     use_bias: bool) -> Dict[str, Any]:
    """in/out projections + depthwise short filter — identical layout (and
    logical param axes) to ``operator.init_hyena``, so the TP rules and the
    block layer see the same tree shape across all hyena variants."""
    D, N = d_model, order
    k_in, k_out, k_short = jax.random.split(key, 3)
    inner = (N + 1) * D
    params: Dict[str, Any] = {
        "in_proj": {
            "w": Ax(
                jax.random.normal(k_in, (D, inner), jnp.float32)
                / jnp.sqrt(D),
                ("embed", "hyena_inner"),
            ),
        },
        "out_proj": {
            "w": Ax(
                jax.random.normal(k_out, (D, D), jnp.float32) / jnp.sqrt(D),
                ("hyena_out", "embed"),
            ),
        },
        "short_filter": Ax(
            jax.random.normal(
                k_short, (inner, short_filter_len), jnp.float32
            ) / jnp.sqrt(short_filter_len),
            ("hyena_inner", None),
        ),
    }
    if use_bias:
        params["in_proj"]["b"] = Ax(
            jnp.zeros((inner,), jnp.float32), ("hyena_inner",)
        )
        params["out_proj"]["b"] = Ax(
            jnp.zeros((D,), jnp.float32), ("embed",)
        )
    return params


def _project_seq_sharded(params, order: int, x: jax.Array, seq_axis):
    """Algorithm 1 under the residual-stream layout: linear (weights
    gathered), seq-sharded short conv (SPMD halo exchange), split."""
    z = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(x.dtype)
    z = shard(z, "data", seq_axis, None)
    z = short_causal_conv(z, params["short_filter"])
    parts = jnp.split(z, order + 1, axis=-1)
    return z, parts[0], parts[1:]


def _decode_project(params, cfg, u_t, cache):
    """Decode-time Algorithm 1 over the tiny rolling short-conv window —
    the same math as ``operator.hyena_decode_step``'s projection block."""
    z = u_t @ params["in_proj"]["w"].astype(u_t.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(u_t.dtype)
    w = params["short_filter"]  # (inner, K)
    hist = cache["short"]  # (B, K-1, inner) newest-first
    zc = z.astype(jnp.float32) * w[:, 0].astype(jnp.float32)[None, :]
    for k in range(1, cfg.short_filter_len):
        zc = zc + hist[:, k - 1].astype(jnp.float32) * (
            w[:, k].astype(jnp.float32)[None, :]
        )
    new_short = jnp.concatenate(
        [z[:, None, :], hist[:, : cfg.short_filter_len - 2]], axis=1
    )
    zc = zc.astype(u_t.dtype)
    parts = jnp.split(zc, cfg.order + 1, axis=-1)
    return new_short, parts[0], parts[1:]


def _out_project(params, v):
    y = v @ params["out_proj"]["w"].astype(v.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(v.dtype)
    return y


def _newest_first(seq: jax.Array, k: int, L: int, dtype) -> jax.Array:
    """(B, L, D) -> (B, k, D) rolling window, newest at index 0, zero-padded
    short prompts (so decode needs no cursor mask)."""
    n = min(L, k)
    recent = jnp.flip(seq[:, L - n:], axis=1).astype(dtype)
    return jnp.pad(recent, ((0, 0), (0, k - n), (0, 0)))


def _window_decode(win: jax.Array, taps: jax.Array):
    """Stacked short-conv decode dot: ``win (N, B, W, D)`` newest-first
    operand windows × lag taps ``taps[:, :, 1:] (N, D, W)`` — one fp32
    einsum for all orders (window index k holds v_{t-1-k}, tap index k+1
    is lag k+1)."""
    return jnp.einsum(
        "nbkd,ndk->nbd", win.astype(jnp.float32),
        taps[:, :, 1:].astype(jnp.float32),
    )


def _roll_window(win_n: jax.Array, v: jax.Array):
    """Prepend the current operand to a newest-first window (drop oldest)."""
    W = win_n.shape[1]
    return jnp.concatenate(
        [v[:, None, :].astype(win_n.dtype), win_n[:, : W - 1]], axis=1
    )


# --------------------------------------------------------------- hyena_se

def _fir_causal_fp32(v: jax.Array, taps: jax.Array) -> jax.Array:
    """Depthwise causal FIR as shifted adds, kept in fp32 (the caller adds
    the skip term before the epilogue downcast — DESIGN.md §7 bit policy).
    Under a sequence-sharded layout the pad+slice lowers to an SPMD halo
    exchange, so SE layers never leave the cp/TP residual layout."""
    B, L, D = v.shape
    v32 = v.astype(jnp.float32)
    y = v32 * taps[:, 0].astype(jnp.float32)[None, None, :]
    for k in range(1, taps.shape[1]):
        shifted = jnp.pad(v32, ((0, 0), (k, 0), (0, 0)))[:, :L]
        y = y + shifted * taps[:, k].astype(jnp.float32)[None, None, :]
    return y


def init_hyena_se(key, cfg: HyenaSEConfig) -> Dict[str, Any]:
    k_proj, k_taps = jax.random.split(key)
    params = _init_projection(
        k_proj, cfg.d_model, cfg.order, cfg.short_filter_len, cfg.use_bias
    )
    # explicit per-order FIR taps — the whole SE filter parameterization
    params["taps"] = Ax(
        jax.random.normal(
            k_taps, (cfg.order, cfg.d_model, cfg.se_len), jnp.float32
        ) / jnp.sqrt(cfg.se_len),
        (None, "hyena_channels", None),
    )
    params["skip"] = Ax(
        jnp.ones((cfg.order, cfg.d_model), jnp.float32),
        (None, "hyena_channels"),
    )
    return params


def apply_hyena_se(
    params, cfg: HyenaSEConfig, x: jax.Array,
    ctx: Optional[ApplyContext] = None,
) -> jax.Array:
    ctx = ctx or DEFAULT_CONTEXT
    cp = getattr(ctx, "cp_axis", None)
    seq_axis = cp or "model"
    _, v, xs = _project_seq_sharded(params, cfg.order, x, seq_axis)
    taps = params["taps"]  # (N, D, K)
    skip = params["skip"]  # (N, D)
    for n in range(cfg.order):
        y = _fir_causal_fp32(v, taps[n])
        y = y + v.astype(jnp.float32) * skip[n].astype(jnp.float32)[None, None, :]
        # downcast BEFORE the gate: identical epilogue to the fused conv
        # backends (fftconv._fused_epilogue)
        v = (xs[n] * y.astype(x.dtype)).astype(x.dtype)
        v = shard(v, "data", seq_axis, None)
    return _out_project(params, v)


def init_hyena_se_cache(
    cfg: HyenaSEConfig, batch: int, max_len: int, dtype=jnp.bfloat16
):
    inner = (cfg.order + 1) * cfg.d_model
    return {
        "short": jnp.zeros(
            (batch, cfg.short_filter_len - 1, inner), dtype
        ),
        # per-order conv operand window, newest-first (bounded — pinned
        # under the paged allocator)
        "win": jnp.zeros(
            (cfg.order, batch, cfg.se_len - 1, cfg.d_model), dtype
        ),
        "t": jnp.zeros((batch,), jnp.int32),
    }


def hyena_se_prefill(
    params, cfg: HyenaSEConfig, x: jax.Array, max_len: int,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, dict]:
    B, L, D = x.shape
    z_pre = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z_pre = z_pre + params["in_proj"]["b"].astype(x.dtype)
    z = short_causal_conv(z_pre, params["short_filter"])
    parts = jnp.split(z, cfg.order + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    taps = params["taps"]
    skip = params["skip"]
    wins = []
    for n in range(cfg.order):
        wins.append(_newest_first(v, cfg.se_len - 1, L, dtype))
        y = _fir_causal_fp32(v, taps[n])
        y = y + v.astype(jnp.float32) * skip[n].astype(jnp.float32)[None, None, :]
        v = (xs[n] * y.astype(x.dtype)).astype(x.dtype)
    out = _out_project(params, v)
    cache = {
        "short": _newest_first(z_pre, cfg.short_filter_len - 1, L, dtype),
        "win": jnp.stack(wins),
        "t": jnp.full((B,), L, jnp.int32),
    }
    return out, cache


def hyena_se_decode_step(params, cfg: HyenaSEConfig, u_t, cache):
    new_short, v, xs = _decode_project(params, cfg, u_t, cache)
    taps = params["taps"]  # (N, D, K)
    skip = params["skip"]
    hist = _window_decode(cache["win"], taps)  # (N, B, D) fp32
    h0 = (taps[:, :, 0] + skip).astype(jnp.float32)  # (N, D) fused rank-1
    new_wins = []
    for n in range(cfg.order):
        new_wins.append(_roll_window(cache["win"][n], v))
        conv_y = hist[n] + v.astype(jnp.float32) * h0[n][None, :]
        v = xs[n] * conv_y.astype(u_t.dtype)
    y = _out_project(params, v)
    out_cache = dict(cache)
    out_cache.update({
        "short": new_short,
        "win": jnp.stack(new_wins),
        "t": cache["t"] + 1,
    })
    return y, out_cache


# --------------------------------------------------------------- hyena_mr

def init_hyena_mr(key, cfg: HyenaMRConfig) -> Dict[str, Any]:
    k_proj, k_filt = jax.random.split(key)
    params = _init_projection(
        k_proj, cfg.d_model, cfg.order, cfg.short_filter_len, cfg.use_bias
    )
    params["filters"] = F.init_hyena_filter(k_filt, cfg.filter)
    return params


def _mr_taps(params, cfg: HyenaMRConfig):
    """Taps on the FIXED ``support``-point grid — length-invariant (the LI
    filter re-evaluates its positional grid per L; MR's regularization is
    exactly this pinned support), so train/prefill/decode all contract
    against identical tap values."""
    h = F.evaluate_filters(params["filters"], cfg.filter, cfg.support)
    skip = F.filter_skip(params["filters"], cfg.filter)
    return h, skip  # (N, D, M) fp32, (N, D)


def _mr_taps_to_len(h: jax.Array, L: int) -> jax.Array:
    M = h.shape[2]
    if L >= M:
        return jnp.pad(h, ((0, 0), (0, 0), (0, L - M)))
    return h[:, :, :L]


def apply_hyena_mr(
    params, cfg: HyenaMRConfig, x: jax.Array,
    ctx: Optional[ApplyContext] = None,
) -> jax.Array:
    """Same layout moves as ``apply_hyena_mixer``: cp stays seq-sharded
    (fft_sp), otherwise channel all-to-all into the conv layout — the
    full-sequence conv goes through the registry backend so MR rides
    blockfft_overlap / fft_sp exactly like LI."""
    ctx = ctx or DEFAULT_CONTEXT
    B, L, D = x.shape
    cp = getattr(ctx, "cp_axis", None)
    seq_axis = cp or "model"
    _, v, xs = _project_seq_sharded(params, cfg.order, x, seq_axis)
    if cp is not None:
        v = shard(v, "data", cp, None)
        xs = [shard(xn, "data", cp, None) for xn in xs]
    else:
        v = shard(v, "data", None, "model")
        xs = [shard(xn, "data", None, "model") for xn in xs]
    h_m, skip = _mr_taps(params, cfg)
    h = _mr_taps_to_len(h_m, L)  # (N, D, L): zero past the support
    backend = get_conv_backend(ctx.conv_backend_for(L))
    backend.validate_len(L)
    for n in range(cfg.order):
        hn = h[n] if cp is not None else shard(h[n], "model", None)
        v = backend(v, hn, skip[n], gate=xs[n]).astype(x.dtype)
        v = shard(v, "data", cp, None) if cp is not None else shard(
            v, "data", None, "model"
        )
    return _out_project(params, v)


def init_hyena_mr_cache(
    cfg: HyenaMRConfig, batch: int, max_len: int, dtype=jnp.bfloat16
):
    inner = (cfg.order + 1) * cfg.d_model
    return {
        "short": jnp.zeros(
            (batch, cfg.short_filter_len - 1, inner), dtype
        ),
        # operand window bounded by the tap support — O(M), not O(max_len)
        "win": jnp.zeros(
            (cfg.order, batch, cfg.support - 1, cfg.d_model), dtype
        ),
        "t": jnp.zeros((batch,), jnp.int32),
    }


def hyena_mr_prefill(
    params, cfg: HyenaMRConfig, x: jax.Array, max_len: int,
    dtype=jnp.bfloat16, *, conv_backend: Optional[str] = None,
) -> Tuple[jax.Array, dict]:
    backend = get_conv_backend(conv_backend)
    B, L, D = x.shape
    backend.validate_len(L)
    z_pre = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z_pre = z_pre + params["in_proj"]["b"].astype(x.dtype)
    z = short_causal_conv(z_pre, params["short_filter"])
    parts = jnp.split(z, cfg.order + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    h_m, skip = _mr_taps(params, cfg)
    h = _mr_taps_to_len(h_m, L)
    wins = []
    for n in range(cfg.order):
        wins.append(_newest_first(v, cfg.support - 1, L, dtype))
        v = backend(v, h[n], skip[n], gate=xs[n]).astype(x.dtype)
    out = _out_project(params, v)
    cache = {
        "short": _newest_first(z_pre, cfg.short_filter_len - 1, L, dtype),
        "win": jnp.stack(wins),
        "t": jnp.full((B,), L, jnp.int32),
        # fp32 taps shared across slots (params + fixed grid only)
        "h": h_m,
        "skip": skip,
    }
    return out, cache


def hyena_mr_decode_step(params, cfg: HyenaMRConfig, u_t, cache):
    h = cache.get("h")
    skip = cache.get("skip")
    if h is None:
        # one-time memoized host-side fallback (same memo as LI — it keys
        # on cfg.filter and the grid length only)
        h, skip = _fallback_decode_taps(params, cfg, cfg.support)
    new_short, v, xs = _decode_project(params, cfg, u_t, cache)
    hist = _window_decode(cache["win"], h)  # (N, B, D) fp32
    h0 = (h[:, :, 0] + skip).astype(jnp.float32)
    new_wins = []
    for n in range(cfg.order):
        new_wins.append(_roll_window(cache["win"][n], v))
        conv_y = hist[n] + v.astype(jnp.float32) * h0[n][None, :]
        v = xs[n] * conv_y.astype(u_t.dtype)
    y = _out_project(params, v)
    out_cache = dict(cache)
    out_cache.update({
        "short": new_short,
        "win": jnp.stack(new_wins),
        "t": cache["t"] + 1,
    })
    return y, out_cache


# ----------------------------------------------------------- registration

@register_mixer
class HyenaLIMixer(HyenaMixer):
    """The long implicit operator under its multi-hybrid name: identical to
    ``hyena`` in every contract — registered separately so `SE-MR-LI`
    patterns name all three variants uniformly."""

    name = "hyena_li"


@register_mixer
class HyenaSEMixer(TokenMixer):
    """Short-explicit hyena: FIR taps as parameters, O(se_len) decode
    state, no channel all-to-all (stays in the residual sharding)."""

    name = "hyena_se"
    attention_free = True
    subquadratic = True

    def make_config(self, cfg) -> HyenaSEConfig:
        return HyenaSEConfig(
            d_model=cfg.d_model,
            order=cfg.hyena_order,
            se_len=cfg.hyena_se_len,
        )

    def init(self, key, mc):
        return init_hyena_se(key, mc)

    def apply(self, params, mc, h, ctx: ApplyContext):
        return apply_hyena_se(params, mc, h, ctx)

    def init_cache(self, mc, batch, max_len, dtype):
        return init_hyena_se_cache(mc, batch, max_len, dtype)

    def prefill(self, params, mc, h, max_len, dtype, ctx: ApplyContext):
        if ctx.pos_offset:
            # window stitching across chunked prefill is unimplemented
            # (the rolling windows only see the current chunk)
            raise NotImplementedError(
                "hyena_se prefill does not support pos_offset != 0"
            )
        return hyena_se_prefill(params, mc, h, max_len, dtype)

    def decode_step(self, params, mc, h_t, cache):
        return hyena_se_decode_step(params, mc, h_t, cache)

    def cache_slot_axes(self, mc) -> dict:
        return {"win": 1}

    def cache_page_axes(self, mc) -> dict:
        return {}  # all leaves are bounded windows / cursors: pinned

    def cache_shard_axes(self, mc) -> dict:
        return {
            "short": ("cache_slots", None, "hyena_inner"),
            "win": (None, "cache_slots", None, "hyena_channels"),
        }

    def state_bytes(self, cfg, max_len: int) -> int:
        mc = self.make_config(cfg)
        D, N = mc.d_model, mc.order
        inner = (N + 1) * D
        short = (mc.short_filter_len - 1) * inner
        win = N * (mc.se_len - 1) * D
        return (short + win) * 2 + 4  # bf16 windows + int32 cursor

    def flops(self, cfg, L: int) -> float:
        mc = self.make_config(cfg)
        D, N, K = mc.d_model, mc.order, mc.short_filter_len
        proj = (N + 1) * D * D + D * D
        short = (N + 1) * D * K
        fir = N * D * mc.se_len + N * D  # taps + skip
        return 2.0 * L * (proj + short + fir)


@register_mixer
class HyenaMRMixer(TokenMixer):
    """Medium-regularized hyena: the implicit filter FFN on a fixed
    ``support`` grid — length-invariant taps, O(support) decode state, the
    full-sequence conv still on the registry (autotuned) backends."""

    name = "hyena_mr"
    attention_free = True
    subquadratic = True

    def make_config(self, cfg) -> HyenaMRConfig:
        return HyenaMRConfig(
            d_model=cfg.d_model,
            order=cfg.hyena_order,
            support=cfg.hyena_mr_support,
            filter=F.FilterConfig(
                d_model=cfg.d_model,
                order=cfg.hyena_order,
                ffn_width=cfg.hyena_filter_width,
                ffn_depth=cfg.hyena_filter_depth,
                pos_dim=cfg.hyena_pos_dim,
                sine_freq=cfg.hyena_sine_freq,
                decay_fast=cfg.hyena_decay[0],
                decay_slow=cfg.hyena_decay[1],
            ),
        )

    def init(self, key, mc):
        return init_hyena_mr(key, mc)

    def apply(self, params, mc, h, ctx: ApplyContext):
        return apply_hyena_mr(params, mc, h, ctx)

    def init_cache(self, mc, batch, max_len, dtype):
        return init_hyena_mr_cache(mc, batch, max_len, dtype)

    def prefill(self, params, mc, h, max_len, dtype, ctx: ApplyContext):
        if ctx.pos_offset:
            raise NotImplementedError(
                "hyena_mr prefill does not support pos_offset != 0"
            )
        return hyena_mr_prefill(
            params, mc, h, max_len, dtype,
            conv_backend=ctx.conv_backend_for(h.shape[1]),
        )

    def decode_step(self, params, mc, h_t, cache):
        return hyena_mr_decode_step(params, mc, h_t, cache)

    def cache_slot_axes(self, mc) -> dict:
        # taps depend only on params + the fixed grid: shared across slots
        return {"win": 1, "h": -1, "skip": -1}

    def cache_page_axes(self, mc) -> dict:
        return {}  # support-bounded windows: pinned (paging buys nothing)

    def cache_shard_axes(self, mc) -> dict:
        return {
            "short": ("cache_slots", None, "hyena_inner"),
            "win": (None, "cache_slots", None, "hyena_channels"),
            "h": (None, "hyena_channels", None),
            "skip": (None, "hyena_channels"),
        }

    def state_bytes(self, cfg, max_len: int) -> int:
        mc = self.make_config(cfg)
        D, N = mc.d_model, mc.order
        inner = (N + 1) * D
        short = (mc.short_filter_len - 1) * inner
        win = N * (mc.support - 1) * D
        taps = N * D * mc.support + N * D  # fp32 shared taps + skip
        return (short + win) * 2 + taps * 4 + 4

    def flops(self, cfg, L: int) -> float:
        import math

        mc = self.make_config(cfg)
        D, N, K = mc.d_model, mc.order, mc.short_filter_len
        fc = mc.filter
        proj = (N + 1) * D * D + D * D
        short = (N + 1) * D * K
        fftconv = 5 * N * D * math.log2(max(L, 2))
        filt = (
            fc.pos_dim * fc.ffn_width
            + (fc.ffn_depth - 1) * fc.ffn_width * fc.ffn_width
            + fc.ffn_width * N * D
        )
        return 2.0 * L * (proj + short + fftconv + filt)
