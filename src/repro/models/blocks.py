"""Composable transformer-family block: norm → token mixer → residual,
norm → channel mixer (dense MLP / MoE / none) → residual.

Token mixers are pluggable by name — the paper's drop-in-replacement claim
is realized here: any attention arch runs with ``--mixer hyena``.  This
module contains **zero** mixer-specific dispatch: every mixer operation
(config, init, apply, cache, prefill, decode) goes through the
:mod:`repro.models.mixer_api` registry, so registering a new mixer never
touches this file.  Layer stacks are built as ``n_groups`` repeats of a
``pattern`` (e.g. RecurrentGemma's ("rglru", "rglru", "local_attention")),
with per-position parameters stacked along a leading axis and the stack
executed with ``lax.scan`` so compile time / HLO size is depth-independent.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as MOE
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.mixer_api import DEFAULT_CONTEXT, ApplyContext, get_mixer


def mixer_config(cfg: ModelConfig, mixer: str):
    """ModelConfig -> the named mixer's own config (registry delegate)."""
    return get_mixer(mixer).make_config(cfg)


def _moe_config(cfg: ModelConfig) -> "MOE.MoEConfig":
    return MOE.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, mlp=cfg.mlp,
    )


def _has_channel_mixer(cfg: ModelConfig) -> bool:
    return cfg.moe or cfg.d_ff > 0


# ------------------------------------------------------------------- block

def init_block(key, cfg: ModelConfig, mixer: str) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    m = get_mixer(mixer)
    p: Dict[str, Any] = {
        "norm1": init_norm(cfg.d_model, cfg.norm),
        "mixer": m.init(k1, m.make_config(cfg)),
    }
    if _has_channel_mixer(cfg):
        p["norm2"] = init_norm(cfg.d_model, cfg.norm)
        if cfg.moe:
            p["moe"] = MOE.init_moe(k2, _moe_config(cfg))
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp)
    return p


def mixer_branch(
    params, cfg: ModelConfig, mixer: str, x: jax.Array,
    ctx: Optional[ApplyContext] = None,
) -> jax.Array:
    """``norm1 → token mixer → layout pin``: the F branch of the block.

    Shared by the standard residual path (:func:`apply_block`) and the
    reversible dual-stream coupling (:mod:`repro.models.reversible`) so both
    wirings evaluate the exact same sub-layer math.
    """
    from repro.distributed.ctx import shard

    ctx = ctx or DEFAULT_CONTEXT
    m = get_mixer(mixer)
    h = apply_norm(params["norm1"], x, cfg.norm)
    h = m.apply(params["mixer"], m.make_config(cfg), h, ctx)
    # pin the sub-layer output to the residual-stream layout *before* the
    # add: row-parallel partial sums then lower to reduce-scatter instead of
    # a full all-reduce (16x fewer bytes at TP=16) — EXPERIMENTS.md §Perf.
    if h.ndim == 3:
        h = shard(h, "data", "model", None)
    return h


def channel_branch(
    params, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """``norm2 → MLP/MoE → layout pin``: the G branch of the block.

    Returns ``(h, aux)``; ``aux`` carries the MoE load-balance / router
    z-loss terms (empty dict for dense MLPs).  Callers must only invoke this
    when :func:`_has_channel_mixer` is true.
    """
    from repro.distributed.ctx import shard

    h = apply_norm(params["norm2"], x, cfg.norm)
    if cfg.moe:
        h, aux = MOE.apply_moe(params["moe"], _moe_config(cfg), h)
    else:
        h = apply_mlp(params["mlp"], h, cfg.mlp)
        aux = {}
    if h.ndim == 3:
        h = shard(h, "data", "model", None)
    return h, aux


def apply_block(
    params, cfg: ModelConfig, mixer: str, x: jax.Array,
    ctx: Optional[ApplyContext] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = x + mixer_branch(params, cfg, mixer, x, ctx)
    aux: Dict[str, jax.Array] = {}
    if _has_channel_mixer(cfg):
        h, aux = channel_branch(params, cfg, x)
        x = x + h
    return x, aux


# ------------------------------------------------------------------- cache

def init_block_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int, dtype):
    m = get_mixer(mixer)
    return m.init_cache(m.make_config(cfg), batch, max_len, dtype)


def block_prefill(
    params, cfg: ModelConfig, mixer: str, x: jax.Array, max_len: int,
    dtype=jnp.bfloat16, ctx: Optional[ApplyContext] = None,
) -> Tuple[jax.Array, Any]:
    """Full-sequence forward that also returns a populated decode cache."""
    ctx = ctx or DEFAULT_CONTEXT
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    h = apply_norm(params["norm1"], x, cfg.norm)
    h, cache = m.prefill(params["mixer"], mc, h, max_len, dtype, ctx)
    x = x + h
    if _has_channel_mixer(cfg):
        h = apply_norm(params["norm2"], x, cfg.norm)
        if cfg.moe:
            h, _ = MOE.apply_moe(params["moe"], _moe_config(cfg), h)
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp)
        x = x + h
    return x, cache


def block_decode(
    params, cfg: ModelConfig, mixer: str, x_t: jax.Array, cache
) -> Tuple[jax.Array, Any]:
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    h = apply_norm(params["norm1"], x_t, cfg.norm)
    h, cache = m.decode_step(params["mixer"], mc, h, cache)
    x_t = x_t + h
    if _has_channel_mixer(cfg):
        h = apply_norm(params["norm2"], x_t, cfg.norm)
        if cfg.moe:
            h, _ = MOE.apply_moe(params["moe"], _moe_config(cfg), h[:, None, :])
            h = h[:, 0]
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp)
        x_t = x_t + h
    return x_t, cache
