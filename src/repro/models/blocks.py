"""Composable transformer-family block: norm → token mixer → residual,
norm → channel mixer (dense MLP / MoE / none) → residual.

Token mixers are pluggable by name — the paper's drop-in-replacement claim
is realized here: any attention arch runs with ``--mixer hyena``.  Layer
stacks are built as ``n_groups`` repeats of a ``pattern`` (e.g. Recurrent-
Gemma's ("rglru", "rglru", "local_attention")), with per-position parameters
stacked along a leading axis and the stack executed with ``lax.scan`` so
compile time / HLO size is depth-independent.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import filters as HF
from repro.core.operator import HyenaConfig
from repro.models import attention as ATT
from repro.models import hyena as HY
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssd as SSD
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm

MIXERS = ("attention", "local_attention", "hyena", "ssd", "rglru")


# ------------------------------------------------------------ mixer configs

def mixer_config(cfg: ModelConfig, mixer: str):
    if mixer in ("attention", "local_attention"):
        return ATT.AttentionConfig(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            qkv_bias=cfg.qkv_bias,
            rope_theta=cfg.rope_theta,
            window=cfg.local_window if mixer == "local_attention" else None,
        )
    if mixer == "hyena":
        return HyenaConfig(
            d_model=cfg.d_model,
            order=cfg.hyena_order,
            filter=HF.FilterConfig(
                d_model=cfg.d_model,
                order=cfg.hyena_order,
                ffn_width=cfg.hyena_filter_width,
                ffn_depth=cfg.hyena_filter_depth,
                pos_dim=cfg.hyena_pos_dim,
                sine_freq=cfg.hyena_sine_freq,
                decay_fast=cfg.hyena_decay[0],
                decay_slow=cfg.hyena_decay[1],
                max_support=cfg.hyena_max_support,
            ),
        )
    if mixer == "ssd":
        return SSD.SSDConfig(
            d_model=cfg.d_model,
            d_state=cfg.ssm_state or 128,
            head_dim=cfg.ssd_head_dim,
            expand=cfg.ssd_expand,
        )
    if mixer == "rglru":
        return RG.RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.rnn_width)
    raise ValueError(f"unknown mixer {mixer}")


def _has_channel_mixer(cfg: ModelConfig) -> bool:
    return cfg.moe or cfg.d_ff > 0


# ------------------------------------------------------------------- block

def init_block(key, cfg: ModelConfig, mixer: str) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    mc = mixer_config(cfg, mixer)
    inits = {
        "attention": ATT.init_attention,
        "local_attention": ATT.init_attention,
        "hyena": HY.init_hyena_mixer,
        "ssd": SSD.init_ssd,
        "rglru": RG.init_rglru,
    }
    p: Dict[str, Any] = {
        "norm1": init_norm(cfg.d_model, cfg.norm),
        "mixer": inits[mixer](k1, mc),
    }
    if _has_channel_mixer(cfg):
        p["norm2"] = init_norm(cfg.d_model, cfg.norm)
        if cfg.moe:
            p["moe"] = MOE.init_moe(
                k2,
                MOE.MoEConfig(
                    d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                    mlp=cfg.mlp,
                ),
            )
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp)
    return p


def apply_block(
    params, cfg: ModelConfig, mixer: str, x: jax.Array, *, pos_offset: int = 0,
    conv_backend: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    from repro.distributed.ctx import shard

    mc = mixer_config(cfg, mixer)
    h = apply_norm(params["norm1"], x, cfg.norm)
    if mixer in ("attention", "local_attention"):
        h = ATT.apply_attention(params["mixer"], mc, h, pos_offset=pos_offset)
    elif mixer == "hyena":
        h = HY.apply_hyena_mixer(
            params["mixer"], mc, h, pos_offset=pos_offset, conv_backend=conv_backend
        )
    elif mixer == "ssd":
        h = SSD.apply_ssd(params["mixer"], mc, h, pos_offset=pos_offset)
    elif mixer == "rglru":
        h = RG.apply_rglru(params["mixer"], mc, h, pos_offset=pos_offset)
    # pin the sub-layer output to the residual-stream layout *before* the
    # add: row-parallel partial sums then lower to reduce-scatter instead of
    # a full all-reduce (16x fewer bytes at TP=16) — EXPERIMENTS.md §Perf.
    if h.ndim == 3:
        h = shard(h, "data", "model", None)
    x = x + h
    aux: Dict[str, jax.Array] = {}
    if _has_channel_mixer(cfg):
        h = apply_norm(params["norm2"], x, cfg.norm)
        if cfg.moe:
            h, aux = MOE.apply_moe(
                params["moe"],
                MOE.MoEConfig(
                    d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                    mlp=cfg.mlp,
                ),
                h,
            )
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp)
        if h.ndim == 3:
            h = shard(h, "data", "model", None)
        x = x + h
    return x, aux


# ------------------------------------------------------------------- cache

def init_block_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int, dtype):
    mc = mixer_config(cfg, mixer)
    if mixer in ("attention", "local_attention"):
        return ATT.init_kv_cache(mc, batch, max_len, dtype)
    if mixer == "hyena":
        return HY.init_hyena_cache(mc, batch, max_len, dtype)
    if mixer == "ssd":
        return SSD.init_ssd_cache(mc, batch, max_len, dtype)
    if mixer == "rglru":
        return RG.init_rglru_cache(mc, batch, max_len, dtype)
    raise ValueError(mixer)


def block_prefill(
    params, cfg: ModelConfig, mixer: str, x: jax.Array, max_len: int,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Any]:
    """Full-sequence forward that also returns a populated decode cache."""
    mc = mixer_config(cfg, mixer)
    h = apply_norm(params["norm1"], x, cfg.norm)
    if mixer in ("attention", "local_attention"):
        h, cache = ATT.attention_prefill(params["mixer"], mc, h, max_len, dtype)
    elif mixer == "hyena":
        h, cache = HY.hyena_prefill(params["mixer"], mc, h, max_len, dtype)
    elif mixer == "ssd":
        h, cache = SSD.ssd_prefill(params["mixer"], mc, h, max_len, dtype)
    elif mixer == "rglru":
        h, cache = RG.rglru_prefill(params["mixer"], mc, h, max_len, dtype)
    else:
        raise ValueError(mixer)
    x = x + h
    if _has_channel_mixer(cfg):
        h = apply_norm(params["norm2"], x, cfg.norm)
        if cfg.moe:
            h, _ = MOE.apply_moe(
                params["moe"],
                MOE.MoEConfig(
                    d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                    mlp=cfg.mlp,
                ),
                h,
            )
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp)
        x = x + h
    return x, cache


def block_decode(
    params, cfg: ModelConfig, mixer: str, x_t: jax.Array, cache
) -> Tuple[jax.Array, Any]:
    mc = mixer_config(cfg, mixer)
    h = apply_norm(params["norm1"], x_t, cfg.norm)
    if mixer in ("attention", "local_attention"):
        h, cache = ATT.attention_decode_step(params["mixer"], mc, h, cache)
    elif mixer == "hyena":
        h, cache = HY.hyena_mixer_decode(params["mixer"], mc, h, cache)
    elif mixer == "ssd":
        h, cache = SSD.ssd_decode_step(params["mixer"], mc, h, cache)
    elif mixer == "rglru":
        h, cache = RG.rglru_decode_step(params["mixer"], mc, h, cache)
    x_t = x_t + h
    if _has_channel_mixer(cfg):
        h = apply_norm(params["norm2"], x_t, cfg.norm)
        if cfg.moe:
            h, _ = MOE.apply_moe(
                params["moe"],
                MOE.MoEConfig(
                    d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                    mlp=cfg.mlp,
                ),
                h[:, None, :],
            )
            h = h[:, 0]
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp)
        x_t = x_t + h
    return x_t, cache
