"""Reversible dual-stream coupling over the scan-stacked depth (DESIGN.md §15).

The standard block is a single-stream residual: ``x += Mixer(norm(x));
x += MLP(norm(x))``.  Backward through a depth-``N`` stack needs the ``N``
saved residual streams (or remat re-forwards).  The reversible substrate
instead threads **two** streams through an additive coupling per block::

    y1 = x1 + F(x2)      F = norm1 → token mixer   (blocks.mixer_branch)
    y2 = x2 + G(y1)      G = norm2 → MLP / MoE     (blocks.channel_branch)

which is exactly invertible::

    x2 = y2 - G(y1)
    x1 = y1 - F(x2)

so the backward pass can *reconstruct* every intermediate stream from the
outputs instead of saving it.  The whole group scan is wrapped in one
``jax.custom_vjp`` whose residuals are just ``(stacked params, y1, y2)`` —
wrapping per-group would be useless, since ``lax.scan``'s own AD would
still save the carry at every step.  The backward pass is a single
``lax.scan(..., reverse=True)`` that per group (a) inverts the coupling to
recover the group's input streams and (b) runs ``jax.vjp`` through the
recomputed group forward, emitting per-group parameter cotangents as scan
outputs.  Depth-resident activation memory is therefore O(1): two streams
plus one group's recompute workspace, regardless of ``n_layers``.

Notes:

- This is a **different function** from the standard single-stream stack
  (the streams diverge after the first block), so "grad parity" means: the
  custom-VJP backward matches plain autodiff *of the same reversible
  wiring* (see :func:`reference_vjp`), not the standard path's gradients.
- Training-only transform: prefill/decode/serve never consult the flag.
- MoE aux losses survive the coupling: the per-group ``(2,)`` aux vector is
  a scan output of the forward, and its cotangent rows are replayed into
  the matching group's recomputed VJP in the backward.
- Composition: the Megatron-SP / ``cp_axis`` sequence-sharding constraints
  are pinned on *both* streams at group boundaries (same layout as the
  standard scan carry); remat is a no-op here — the custom VJP already
  dictates what is saved, so ``lm.forward`` skips ``jax.checkpoint`` on the
  reversible path.
- Exactness: the inverse is algebraically exact but floating-point
  reconstruction ``(a + b) - b`` rounds, so gradients match autodiff to
  ~1e-5 rel at fp32.  The dual streams always ride in fp32 while branches
  compute at the policy dtype (cast at the branch input): under bf16 the
  reconstructed fp32 stream re-rounds to the bit-identical bf16 branch
  input, so recompute noise does not compound and bf16 parity is *tighter*
  than fp32 (exact on CPU; tests/test_reversible.py documents 5e-3).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.mixer_api import DEFAULT_CONTEXT, ApplyContext


def _seq_axis(ctx) -> str:
    return getattr(ctx, "cp_axis", None) or "model"


def _pin(ctx, x: jax.Array) -> jax.Array:
    """Residual-stream layout constraint (both streams, group boundaries)."""
    from repro.distributed.ctx import shard

    return shard(x, "data", _seq_axis(ctx), None)


# ---------------------------------------------------------------- coupling

def coupling_apply(
    params, cfg: ModelConfig, mixer: str, x1: jax.Array, x2: jax.Array,
    ctx: Optional[ApplyContext] = None, branch_dtype=None,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """One reversible block: ``y1 = x1 + F(x2); y2 = x2 + G(y1)``.

    The streams ride at their own (fp32) dtype; ``branch_dtype`` is the
    compute dtype the branches see — casting the branch *input* down keeps
    bf16 compute exactly as fast while the stream adds/subtracts stay fp32,
    so the backward's reconstructed stream re-rounds to the *identical*
    branch input and recompute noise does not compound across depth.
    """
    ctx = ctx or DEFAULT_CONTEXT
    bd = branch_dtype or x1.dtype
    y1 = x1 + B.mixer_branch(params, cfg, mixer, x2.astype(bd), ctx)
    aux: Dict[str, jax.Array] = {}
    if B._has_channel_mixer(cfg):
        h, aux = B.channel_branch(params, cfg, y1.astype(bd))
        y2 = x2 + h
    else:
        y2 = x2
    return y1, y2, aux


def coupling_inverse(
    params, cfg: ModelConfig, mixer: str, y1: jax.Array, y2: jax.Array,
    ctx: Optional[ApplyContext] = None, branch_dtype=None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact inverse of :func:`coupling_apply` (same branch evaluations)."""
    ctx = ctx or DEFAULT_CONTEXT
    bd = branch_dtype or y1.dtype
    if B._has_channel_mixer(cfg):
        h, _ = B.channel_branch(params, cfg, y1.astype(bd))
        x2 = y2 - h
    else:
        x2 = y2
    x1 = y1 - B.mixer_branch(params, cfg, mixer, x2.astype(bd), ctx)
    return x1, x2


# ------------------------------------------------------------- group level

def _group_apply(cfg: ModelConfig, ctx, bd, gp, x1, x2):
    """One pattern group of couplings; returns (y1, y2, aux_sum (2,))."""
    x1, x2 = _pin(ctx, x1), _pin(ctx, x2)
    aux_sum = jnp.zeros((2,), jnp.float32)
    for p, mixer in enumerate(cfg.pattern):
        x1, x2, aux = coupling_apply(
            gp[p], cfg, mixer, x1, x2, ctx, branch_dtype=bd
        )
        if aux:
            aux_sum = aux_sum + jnp.stack(
                [aux["moe_load_balance"], aux["moe_z_loss"]]
            )
    return _pin(ctx, x1), _pin(ctx, x2), aux_sum


def _group_inverse(cfg: ModelConfig, ctx, bd, gp, y1, y2):
    y1, y2 = _pin(ctx, y1), _pin(ctx, y2)
    for p in reversed(range(len(cfg.pattern))):
        y1, y2 = coupling_inverse(
            gp[p], cfg, cfg.pattern[p], y1, y2, ctx, branch_dtype=bd
        )
    return _pin(ctx, y1), _pin(ctx, y2)


# --------------------------------------------------------- scan-level VJP

def _scan_impl(cfg: ModelConfig, ctx, bd, groups, x1, x2):
    """Plain forward: scan the coupling over the stacked groups."""

    def body(carry, gp):
        a, b = carry
        a, b, aux = _group_apply(cfg, ctx, bd, gp, a, b)
        return (a, b), aux

    (y1, y2), aux_stack = jax.lax.scan(body, (x1, x2), groups)
    return y1, y2, aux_stack


_rev_scan = jax.custom_vjp(_scan_impl, nondiff_argnums=(0, 1, 2))


def _rev_fwd(cfg, ctx, bd, groups, x1, x2):
    y1, y2, aux_stack = _scan_impl(cfg, ctx, bd, groups, x1, x2)
    # O(1) residuals in depth: params + the two *output* streams only.
    return (y1, y2, aux_stack), (groups, y1, y2)


def _rev_bwd(cfg, ctx, bd, res, cots):
    groups, y1, y2 = res
    dy1, dy2, daux = cots

    def body(carry, xs):
        c_y1, c_y2, c_dy1, c_dy2 = carry
        gp, daux_g = xs
        # (a) invert the coupling: recover this group's *input* streams
        x1, x2 = _group_inverse(cfg, ctx, bd, gp, c_y1, c_y2)
        x1 = jax.lax.stop_gradient(x1)
        x2 = jax.lax.stop_gradient(x2)
        # (b) recompute the group forward under vjp and pull cotangents back
        _, pullback = jax.vjp(
            lambda g, a, b: _group_apply(cfg, ctx, bd, g, a, b), gp, x1, x2
        )
        dgp, dx1, dx2 = pullback((c_dy1, c_dy2, daux_g))
        return (x1, x2, dx1, dx2), dgp

    (x1, x2, dx1, dx2), dgroups = jax.lax.scan(
        body, (y1, y2, dy1, dy2), (groups, daux), reverse=True
    )
    return dgroups, dx1, dx2


_rev_scan.defvjp(_rev_fwd, _rev_bwd)


# tests flip this to compare the custom VJP against plain autodiff of the
# identical wiring (lax.scan AD saves the carry per step — O(depth) memory,
# reference semantics)
_USE_CUSTOM_VJP = True


@contextlib.contextmanager
def reference_vjp():
    """Within this context, differentiate the reversible wiring with plain
    autodiff instead of the reconstruct-and-recompute custom VJP."""
    global _USE_CUSTOM_VJP
    prev = _USE_CUSTOM_VJP
    _USE_CUSTOM_VJP = False
    try:
        yield
    finally:
        _USE_CUSTOM_VJP = prev


def reversible_scan(cfg: ModelConfig, ctx, bd, groups, x1, x2):
    fn = _rev_scan if _USE_CUSTOM_VJP else _scan_impl
    return fn(cfg, ctx, bd, groups, x1, x2)


# ------------------------------------------------------------- entry point

def reversible_forward(
    cfg: ModelConfig, ctx, groups, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Residual stream → dual streams → reversible scan → combined stream.

    Streams initialize as ``x1 = x2 = x`` and recombine as the mean, so a
    zero-depth stack is the identity and the output scale matches the
    single-stream convention.  The streams themselves ride in fp32 — the
    reconstruction ``(a + b) - b`` must not round at the compute dtype, or
    bf16 training would see ~eps·(inverse-chain gain) gradient noise —
    while every branch computes at the incoming (policy) dtype.  Returns
    ``(x_out, aux_stack (n_groups, 2))`` with ``x_out`` back at ``x.dtype``.
    """
    x = _pin(ctx, x)
    bd = x.dtype  # the policy's compute dtype: what the branches see
    x32 = x.astype(jnp.float32)
    y1, y2, aux_stack = reversible_scan(cfg, ctx, bd, tuple(groups), x32, x32)
    out = ((y1 + y2) * 0.5).astype(bd)
    return _pin(ctx, out), aux_stack
