"""GQA/MHA attention mixer: RoPE, optional QKV bias, sliding window,
memory-bounded chunked online-softmax (a pure-XLA flash formulation used for
distributed lowering; the Pallas kernel in repro.kernels is the TPU-native
single-chip version), and KV-cache decode.

Activation sharding (under a mesh): batch → data; queries → model (context
parallelism) for long sequences; KV replicated across model (each device
scans the full key space for its query shard).  See DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.distributed.ctx import shard
from repro.models.layers import apply_rope, dense, init_dense
from repro.models.mixer_api import ApplyContext, TokenMixer, register_mixer

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding-window (local) attention
    chunk_q: int = 512
    chunk_kv: int = 1024


def init_attention(key, cfg: AttentionConfig) -> Dict[str, Any]:
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "q": init_dense(kq, D, H * Dh, ("embed", "attn_hidden"), bias=cfg.qkv_bias),
        "k": init_dense(kk, D, Hkv * Dh, ("embed", "kv_hidden"), bias=cfg.qkv_bias),
        "v": init_dense(kv, D, Hkv * Dh, ("embed", "kv_hidden"), bias=cfg.qkv_bias),
        "o": init_dense(ko, H * Dh, D, ("attn_hidden", "embed")),
    }


def _dense_attention(q, k, v, *, causal, window, q_offset):
    """(B, Lq, H, Dh) x (B, Lk, Hkv, Dh) — small-L direct path."""
    B, Lq, H, Dh = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Lq, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    iq = jnp.arange(Lq)[:, None] + q_offset
    ik = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask = mask & (ik <= iq)
    if window is not None:
        mask = mask & (ik > iq - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Lq, H, Dh).astype(q.dtype)


def chunked_attention(
    q: jax.Array,  # (B, Lq, H, Dh)
    k: jax.Array,  # (B, Lk, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    chunk_kv: int = 1024,
) -> jax.Array:
    """Online-softmax scan over KV chunks: peak memory O(Lq · chunk_kv)
    instead of O(Lq · Lk).  fp32 accumulators."""
    B, Lq, H, Dh = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    if Lk <= chunk_kv:
        return _dense_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    G = H // Hkv
    pad = (-Lk) % chunk_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk_kv
    ks = k.reshape(B, n_chunks, chunk_kv, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, chunk_kv, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    qg = (q / math.sqrt(Dh)).reshape(B, Lq, Hkv, G, Dh)
    iq = jnp.arange(Lq) + q_offset  # absolute query positions

    def step(carry, inputs):
        m, l, acc = carry
        idx, kc, vc = inputs  # (B, C, Hkv, Dh)
        C = kc.shape[1]
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), kc.astype(jnp.float32)
        )
        ik = idx * chunk_kv + jnp.arange(C)
        mask = (ik[None, :] < Lk)
        if causal:
            mask = mask & (ik[None, :] <= iq[:, None])
        if window is not None:
            mask = mask & (ik[None, :] > iq[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Lq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Lq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), ks, vs)
    )
    l = jnp.where(l == 0.0, 1.0, l)
    o = (acc / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(B, Lq, H, Dh)
    return o.astype(q.dtype)


def cp_ring_attention(
    q: jax.Array,  # (B, L, H, Dh), L sharded over `axis`
    k: jax.Array,  # (B, L, Hkv, Dh), L sharded over `axis`
    v: jax.Array,
    *,
    mesh,
    axis: str,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Ring attention over the context-parallel axis: queries AND keys stay
    sequence-sharded; the KV shard rotates around the ring (one ppermute
    per step, P steps) while each shard folds the visiting block into its
    flash-style online-softmax accumulators.  Peak memory O(L/P · L/P) per
    block pair instead of O(L/P · L) for the allgather path; masks use
    absolute positions (``idx · L/P + q_offset``), which is how per-shard
    RoPE/position offsets stay consistent.  Differentiable: the loop is
    python-unrolled and ppermute transposes to the inverse ring.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.ctx import shard_map
    from repro.distributed.spconv import _batch_specs

    B, L, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    P_sz = mesh.shape[axis]
    Lp = L // P_sz
    bspec, _ = _batch_specs(mesh, axis, B)
    qspec = P(bspec, axis, None, None)

    def body(qb, kb, vb):
        idx = jax.lax.axis_index(axis)
        Bl = qb.shape[0]
        qg = (qb.astype(jnp.float32) / math.sqrt(Dh)).reshape(
            Bl, Lp, Hkv, G, Dh
        )
        iq = q_offset + idx * Lp + jnp.arange(Lp)  # absolute query positions
        m = jnp.full((Bl, Hkv, G, Lp), NEG_INF, jnp.float32)
        l = jnp.zeros((Bl, Hkv, G, Lp), jnp.float32)
        acc = jnp.zeros((Bl, Hkv, G, Lp, Dh), jnp.float32)
        kc, vc = kb, vb
        perm = [(i, (i + 1) % P_sz) for i in range(P_sz)]
        for s in range(P_sz):
            src = (idx - s) % P_sz  # owner of the block visiting this step
            ik = q_offset + src * Lp + jnp.arange(Lp)
            sc = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qg, kc.astype(jnp.float32)
            )
            mask = ik[None, :] <= iq[:, None]
            if window is not None:
                mask = mask & (ik[None, :] > iq[:, None] - window)
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            # finite NEG_INF + re-zeroing p under the mask keeps fully
            # masked (future) blocks NaN-free — same pattern as
            # chunked_attention
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32)
            )
            m = m_new
            if s < P_sz - 1:
                kc = jax.lax.ppermute(kc, axis, perm)
                vc = jax.lax.ppermute(vc, axis, perm)
        l = jnp.where(l == 0.0, 1.0, l)
        o = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)
        return o.reshape(Bl, Lp, H, Dh).astype(qb.dtype)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(qspec, P(bspec, axis, None, None), P(bspec, axis, None, None)),
        out_specs=qspec, check=False,
    )
    return fn(q, k, v)


def cp_allgather_attention(
    q, k, v, *, mesh, axis: str, window: Optional[int] = None,
    q_offset: int = 0, chunk_kv: int = 1024,
) -> jax.Array:
    """Masked-allgather fallback for the cp path: queries stay sharded, KV
    all-gathers inside the shard_map body and each shard runs the chunked
    online-softmax with its absolute query offset.  O(L) KV per chip — use
    when the ring's P-step latency loses to one fused all-gather (small P,
    short L)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.ctx import shard_map
    from repro.distributed.spconv import _batch_specs

    B, L, H, Dh = q.shape
    P_sz = mesh.shape[axis]
    Lp = L // P_sz
    bspec, _ = _batch_specs(mesh, axis, B)
    qspec = P(bspec, axis, None, None)

    def body(qb, kb, vb):
        idx = jax.lax.axis_index(axis)
        kf = jax.lax.all_gather(kb, axis, axis=1, tiled=True)
        vf = jax.lax.all_gather(vb, axis, axis=1, tiled=True)
        return chunked_attention(
            qb, kf, vf, causal=True, window=window,
            q_offset=q_offset + idx * Lp, chunk_kv=chunk_kv,
        )

    fn = shard_map(
        body, mesh=mesh, in_specs=(qspec, qspec, qspec), out_specs=qspec,
        check=False,
    )
    return fn(q, k, v)


def apply_attention(
    params, cfg: AttentionConfig, x: jax.Array, *, pos_offset: int = 0,
    cp_axis: Optional[str] = None,
) -> jax.Array:
    """Full-sequence forward (training / prefill). x: (B, L, D).

    With ``cp_axis`` (context-parallel training) the sequence dim of q AND
    kv stays sharded and attention runs the ring (or, with
    ``$REPRO_CP_ATTN=allgather``, the masked-allgather fallback) — no
    full-L KV ever materializes per chip.
    """
    B, L, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(params["q"], x).reshape(B, L, H, Dh)
    k = dense(params["k"], x).reshape(B, L, Hkv, Dh)
    v = dense(params["v"], x).reshape(B, L, Hkv, Dh)
    from repro.distributed.ctx import current_mesh

    mesh = current_mesh()
    use_cp = (
        cp_axis is not None
        and mesh is not None
        and mesh.shape.get(cp_axis, 1) > 1
        and L % mesh.shape[cp_axis] == 0
    )
    if use_cp:
        # sequence stays sharded on q AND kv; constraints before RoPE for
        # the same heads-whole layout reason as below (rope splits Dh,
        # which is unsharded here, so GSPMD's sharded iota is safe)
        q = shard(q, "data", cp_axis, None, None)
        k = shard(k, "data", cp_axis, None, None)
        v = shard(v, "data", cp_axis, None, None)
        pos = jnp.arange(L) + pos_offset
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        import os

        impl = os.environ.get("REPRO_CP_ATTN", "ring")
        fn = cp_allgather_attention if impl == "allgather" else cp_ring_attention
        o = fn(
            q, k, v, mesh=mesh, axis=cp_axis, window=cfg.window,
            q_offset=pos_offset,
        )
        o = shard(o, "data", cp_axis, None, None)
        return dense(params["o"], o.reshape(B, L, H * Dh))
    # context parallelism: queries sharded over model axis, KV replicated.
    # The constraints sit BEFORE RoPE on purpose: a model-sharded qkv
    # weight leaves its activation sharded on the flattened (H·Dh) dim,
    # i.e. split *inside* a head, and rope's split/concat must never see
    # that layout (XLA SPMD mis-partitions it; heads-whole layouts are
    # safe) — same reason the serve path constrains in attention_prefill.
    q = shard(q, "data", "model", None, None)
    k = shard(k, "data", None, None, None)
    v = shard(v, "data", None, None, None)
    pos = jnp.arange(L) + pos_offset
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = chunked_attention(
        q, k, v, causal=True, window=cfg.window, q_offset=pos_offset,
        chunk_kv=cfg.chunk_kv,
    )
    o = shard(o, "data", "model", None, None)
    return dense(params["o"], o.reshape(B, L, H * Dh))


# ------------------------------------------------------------------ decode

def attention_prefill(
    params, cfg: AttentionConfig, x: jax.Array, max_len: int, dtype=jnp.bfloat16,
    *, pos_offset: int = 0,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence forward that also fills the decode cache."""
    B, L, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(params["q"], x).reshape(B, L, H, Dh)
    k = dense(params["k"], x).reshape(B, L, Hkv, Dh)
    v = dense(params["v"], x).reshape(B, L, Hkv, Dh)
    # serve-side layout pin, before RoPE: whole heads on the model axis
    # (never a split Dh — see apply_attention) and the KV layout matching
    # the rule-derived cache sharding the lines below scatter into
    q = shard(q, "data", None, "model", None)
    k = shard(k, "data", None, "model", None)
    v = shard(v, "data", None, "model", None)
    pos = jnp.arange(L) + pos_offset
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = chunked_attention(
        q, k, v, causal=True, window=cfg.window, q_offset=pos_offset,
        chunk_kv=cfg.chunk_kv,
    )
    y = dense(params["o"], o.reshape(B, L, H * Dh))
    cache = init_kv_cache(cfg, B, max_len, dtype)
    size = cache["k"].shape[1]
    if cfg.window is None:
        ck = cache["k"].at[:, :L].set(k.astype(dtype))
        cv = cache["v"].at[:, :L].set(v.astype(dtype))
    else:
        # ring buffer: token j lives at slot j % size; keep the last `size`
        n = min(L, size)
        slots = (jnp.arange(L - n, L)) % size
        ck = cache["k"].at[:, slots].set(k[:, L - n :].astype(dtype))
        cv = cache["v"].at[:, slots].set(v[:, L - n :].astype(dtype))
    return y, {"k": ck, "v": cv, "t": jnp.full((B,), L, jnp.int32)}


def init_kv_cache(cfg: AttentionConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    size = max_len if cfg.window is None else min(cfg.window, max_len)
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        # per-slot write cursor: under continuous batching every batch row
        # is an independent request at its own sequence position
        "t": jnp.zeros((batch,), jnp.int32),
    }


def attention_decode_step(
    params, cfg: AttentionConfig, x_t: jax.Array, cache: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token. x_t: (B, D). Sliding-window caches are rolling buffers of
    size `window`; global caches are length `max_len` with a write cursor.
    The cursor ``t`` is per batch row, so a continuous-batching pool can
    decode slots sitting at different positions in one step."""
    B, D = x_t.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = cache["t"]  # (B,)
    q = dense(params["q"], x_t).reshape(B, 1, H, Dh)
    k = dense(params["k"], x_t).reshape(B, 1, Hkv, Dh)
    v = dense(params["v"], x_t).reshape(B, 1, Hkv, Dh)
    # same pre-RoPE layout pin as prefill: heads whole, Dh never split
    q = shard(q, "data", None, "model", None)
    k = shard(k, "data", None, "model", None)
    v = shard(v, "data", None, "model", None)
    pos = t[:, None].astype(jnp.int32)  # (B, 1) one position per row
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    size = cache["k"].shape[1]
    slot = t % size  # (B,)
    rows = jnp.arange(B)
    ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
    valid = jnp.arange(size)[None, :] <= t[:, None]  # (B, size)
    if cfg.window is not None:
        ages = (t[:, None] - jnp.arange(size)[None, :]) % size  # 0 = newest
        valid = valid & (ages < cfg.window)
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32) / math.sqrt(Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, ck.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, cv.astype(jnp.float32))
    o = o.reshape(B, H * Dh).astype(x_t.dtype)
    y = dense(params["o"], o)
    return y, {"k": ck, "v": cv, "t": t + 1}


# ----------------------------------------------------------- registrations

@register_mixer
class AttentionMixer(TokenMixer):
    """Global causal GQA/MHA — the baseline the paper swaps out."""

    name = "attention"
    attention_free = False
    subquadratic = False

    def make_config(self, cfg) -> AttentionConfig:
        return AttentionConfig(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            qkv_bias=cfg.qkv_bias,
            rope_theta=cfg.rope_theta,
            window=None,
        )

    def init(self, key, mc):
        return init_attention(key, mc)

    def apply(self, params, mc, h, ctx: ApplyContext):
        return apply_attention(
            params, mc, h, pos_offset=ctx.pos_offset,
            cp_axis=getattr(ctx, "cp_axis", None),
        )

    def init_cache(self, mc, batch, max_len, dtype):
        return init_kv_cache(mc, batch, max_len, dtype)

    def prefill(self, params, mc, h, max_len, dtype, ctx: ApplyContext):
        return attention_prefill(
            params, mc, h, max_len, dtype, pos_offset=ctx.pos_offset
        )

    def decode_step(self, params, mc, h_t, cache):
        return attention_decode_step(params, mc, h_t, cache)

    def cache_page_axes(self, mc) -> dict:
        # Global KV grows append-only with the sequence (token j at index
        # j) — the classic vLLM paging target.  Sliding-window rings reuse
        # index j % size, so their state is bounded by the window and
        # stays pinned (LocalAttentionMixer inherits this and returns {}).
        if mc.window is not None:
            return {}
        return {"k": 1, "v": 1}

    def cache_shard_axes(self, mc) -> dict:
        # KV ring buffers shard over the model axis on the head dim (the
        # decode einsums contract per KV head); when the head count can't
        # divide it (GQA: 8 KV heads on a 16-way axis), the lower-priority
        # "kv_seq" rule shards the ring's time dim instead, so a 500K-token
        # cache never falls back to full per-chip replication.  Write
        # cursors replicate — every chip needs every slot's position for
        # its RoPE/validity mask.
        return {
            "k": ("cache_slots", "kv_seq", "heads", None),
            "v": ("cache_slots", "kv_seq", "heads", None),
        }

    def state_bytes(self, cfg, max_len: int) -> int:
        mc = self.make_config(cfg)
        size = max_len if mc.window is None else min(mc.window, max_len)
        # K + V ring buffers (bf16) + int32 write cursor
        return 2 * size * mc.n_kv_heads * mc.head_dim * 2 + 4

    def flops(self, cfg, L: int) -> float:
        mc = self.make_config(cfg)
        D, H, Hkv, Dh = mc.d_model, mc.n_heads, mc.n_kv_heads, mc.head_dim
        span = L if mc.window is None else min(mc.window, L)
        proj = 2 * D * H * Dh + 2 * D * Hkv * Dh  # qkvo
        attn = 2 * span * H * Dh  # QKᵀ + PV (non-param)
        return 2.0 * L * (proj + attn)


@register_mixer
class LocalAttentionMixer(AttentionMixer):
    """Sliding-window attention: O(L·window), ring-buffer decode cache."""

    name = "local_attention"
    subquadratic = True

    def make_config(self, cfg) -> AttentionConfig:
        return dataclasses.replace(
            super().make_config(cfg), window=cfg.local_window
        )
