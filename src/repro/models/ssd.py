"""Mamba-2 SSD (state-space duality) token mixer (arXiv:2405.21060).

Chunked SSD algorithm: within a Q-length chunk the quadratic "attention
like" form is used (MXU matmuls); chunk-to-chunk a recurrent state
``S ∈ R^{H×N×P}`` is carried through a sequential lax.scan.  Decode carries
the same state with O(1) work per token.

Faithful elements: scalar per-head decay ``a = -exp(A_log)``, softplus dt
with bias, grouped B/C (ngroups), width-4 causal conv on (x, B, C), gated
RMSNorm output, D skip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.distributed.ctx import shard
from repro.core.fftconv import short_causal_conv
from repro.models.layers import init_dense, dense


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_ssd(key, cfg: SSDConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    di, G, N, H = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    d_in_proj = 2 * di + 2 * G * N + H  # z, x, B, C, dt
    conv_ch = di + 2 * G * N
    return {
        "in_proj": init_dense(ks[0], cfg.d_model, d_in_proj, ("embed", "ssd_inner")),
        "conv_w": Ax(
            jax.random.normal(ks[1], (conv_ch, cfg.conv_width), jnp.float32)
            / jnp.sqrt(cfg.conv_width),
            ("ssd_inner", None),
        ),
        "A_log": Ax(
            jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)), ("heads",)
        ),
        "dt_bias": Ax(jnp.zeros((H,), jnp.float32), ("heads",)),
        "D": Ax(jnp.ones((H,), jnp.float32), ("heads",)),
        "norm_g": Ax(jnp.zeros((di,), jnp.float32), ("ssd_inner",)),
        "out_proj": init_dense(ks[2], di, cfg.d_model, ("ssd_inner", "embed")),
    }


def _split_proj(cfg: SSDConfig, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    return z, xin, Bm, Cm, dt


def _ssd_scan(cfg: SSDConfig, x, dt, Bm, Cm, A, initial_state=None):
    """Chunked SSD. x: (B, L, H, P); dt: (B, L, H); Bm/Cm: (B, L, G, N).
    Returns y (B, L, H, P) and final state (B, H, N, P)."""
    Bsz, L, H, P = x.shape
    G, N = cfg.n_groups, cfg.d_state
    Q = min(cfg.chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = x.shape[1]
    nc = Lp // Q
    rep = H // G  # heads per group

    def resh(t, tail):
        return t.reshape((Bsz, nc, Q) + tail).transpose((1, 0, 2) + tuple(range(3, 3 + len(tail))))

    xs = resh(x, (H, P))  # (nc, B, Q, H, P)
    dts = resh(dt, (H,))
    Bs = resh(Bm, (G, N))
    Cs = resh(Cm, (G, N))

    def chunk_step(S, inp):
        xq, dtq, Bq, Cq = inp  # (B, Q, H, P), (B, Q, H), (B, Q, G, N)
        da = dtq * A[None, None, :]  # (B, Q, H) log-decay increments (<0)
        s_cum = jnp.cumsum(da, axis=1)  # (B, Q, H) cumulative log decay
        total = s_cum[:, -1]  # (B, H)
        # -- intra-chunk (quadratic within chunk)
        Bh = jnp.repeat(Bq, rep, axis=2)  # (B, Q, H, N)
        Ch = jnp.repeat(Cq, rep, axis=2)
        scores = jnp.einsum("bqhn,bkhn->bhqk", Ch, Bh)  # (B, H, Q, Q)
        decay = s_cum[:, :, None, :] - s_cum[:, None, :, :]  # (B, Q, K, H)
        decay = decay.transpose(0, 3, 1, 2)  # (B, H, Q, K)
        iq = jnp.arange(Q)
        causal = iq[:, None] >= iq[None, :]
        # mask the exponent (not the output): exp of acausal entries can
        # overflow to inf, which would leak NaN through the where-vjp.
        gate = jnp.exp(jnp.where(causal[None, None], decay, -1e30))
        xdt = xq * dtq[..., None]  # (B, Q, H, P) — dt-weighted input
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", scores * gate, xdt)
        # -- inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "bqhn,bhnp->bqhp", Ch * jnp.exp(s_cum)[..., None], S
        )
        # -- state update
        w = jnp.exp(total[:, None, :] - s_cum)  # decay from step q to chunk end
        S_new = S * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bqhn,bqhp->bhnp", Bh * w[..., None], xdt
        )
        return S_new, y_intra + y_inter

    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, N, P), jnp.float32)
    S, ys = jax.lax.scan(chunk_step, initial_state, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, Lp, H, P)
    return y[:, :L], S


def apply_ssd(params, cfg: SSDConfig, x: jax.Array, *, pos_offset: int = 0):
    B, L, D = x.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = dense(params["in_proj"], x)
    zxbcdt = shard(zxbcdt, "data", None, "model")
    z, xin, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(short_causal_conv(xbc, params["conv_w"]))
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative
    xh = xin.reshape(B, L, H, P).astype(jnp.float32)
    Bmh = Bm.reshape(B, L, G, N).astype(jnp.float32)
    Cmh = Cm.reshape(B, L, G, N).astype(jnp.float32)
    y, _ = _ssd_scan(cfg, xh, dt, Bmh, Cmh, A)
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(B, L, cfg.d_inner).astype(x.dtype)
    # gated RMSNorm (Mamba-2)
    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_g"])).astype(x.dtype)
    return dense(params["out_proj"], g)


# ------------------------------------------------------------------ decode

def ssd_prefill(
    params, cfg: SSDConfig, x: jax.Array, max_len: int, dtype=jnp.bfloat16,
    *, pos_offset: int = 0,
):
    """Forward + capture (conv history, final SSD state)."""
    B, L, D = x.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = dense(params["in_proj"], x)
    z, xin, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    xbc_raw = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(short_causal_conv(xbc_raw, params["conv_w"]))
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xin.reshape(B, L, H, P).astype(jnp.float32)
    Bmh = Bm.reshape(B, L, G, N).astype(jnp.float32)
    Cmh = Cm.reshape(B, L, G, N).astype(jnp.float32)
    y, S = _ssd_scan(cfg, xh, dt, Bmh, Cmh, A)
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(B, L, cfg.d_inner).astype(x.dtype)
    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_g"])).astype(x.dtype)
    out = dense(params["out_proj"], g)
    K = cfg.conv_width
    n = min(L, K - 1)
    hist = jnp.flip(xbc_raw[:, L - n :], axis=1).astype(dtype)
    hist = jnp.pad(hist, ((0, 0), (0, K - 1 - n), (0, 0)))
    cache = {"conv": hist, "state": S, "t": jnp.full((B,), L, jnp.int32)}
    return out, cache


def init_ssd_cache(cfg: SSDConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    conv_ch = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros(
            (batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32
        ),
        "t": jnp.zeros((batch,), jnp.int32),
    }


def ssd_decode_step(params, cfg: SSDConfig, x_t: jax.Array, cache):
    """x_t: (B, D) one token; O(1) state update."""
    B, D = x_t.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = dense(params["in_proj"], x_t)
    z, xin, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)  # (B, conv_ch)
    w = params["conv_w"]  # (conv_ch, K)
    hist = cache["conv"]
    acc = xbc.astype(jnp.float32) * w[:, 0][None]
    for k in range(1, cfg.conv_width):
        acc = acc + hist[:, k - 1].astype(jnp.float32) * w[:, k][None]
    new_conv = jnp.concatenate(
        [xbc[:, None, :].astype(hist.dtype), hist[:, : cfg.conv_width - 2]], axis=1
    )
    xbc = jax.nn.silu(acc).astype(x_t.dtype)
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :])  # (B, H)
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bm.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    S = cache["state"] * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh, xh * dt[..., None]
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, S) + xh * params["D"][None, :, None]
    y = y.reshape(B, cfg.d_inner).astype(x_t.dtype)
    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_g"])).astype(x_t.dtype)
    y = dense(params["out_proj"], g)
    return y, {"conv": new_conv, "state": S, "t": cache["t"] + 1}


# ----------------------------------------------------------- registration

from repro.models.mixer_api import ApplyContext, TokenMixer, register_mixer  # noqa: E402


@register_mixer
class SSDMixer(TokenMixer):
    """Mamba-2 state-space duality mixer: O(1) recurrent decode state."""

    name = "ssd"
    attention_free = True
    subquadratic = True

    def make_config(self, cfg) -> SSDConfig:
        return SSDConfig(
            d_model=cfg.d_model,
            d_state=cfg.ssm_state or 128,
            head_dim=cfg.ssd_head_dim,
            expand=cfg.ssd_expand,
        )

    def init(self, key, mc):
        return init_ssd(key, mc)

    def apply(self, params, mc, h, ctx: ApplyContext):
        return apply_ssd(params, mc, h, pos_offset=ctx.pos_offset)

    def init_cache(self, mc, batch, max_len, dtype):
        return init_ssd_cache(mc, batch, max_len, dtype)

    def prefill(self, params, mc, h, max_len, dtype, ctx: ApplyContext):
        return ssd_prefill(
            params, mc, h, max_len, dtype, pos_offset=ctx.pos_offset
        )

    def decode_step(self, params, mc, h_t, cache):
        return ssd_decode_step(params, mc, h_t, cache)

    def cache_shard_axes(self, mc) -> dict:
        # the SSM state shards over heads (the recurrence is per-head);
        # the short-conv history's channel dim is the concatenated
        # x/B/C projection — no clean logical axis, so it replicates
        return {
            "state": ("cache_slots", "heads", None, None),
        }

    def state_bytes(self, cfg, max_len: int) -> int:
        mc = self.make_config(cfg)
        conv_ch = mc.d_inner + 2 * mc.n_groups * mc.d_state
        conv = (mc.conv_width - 1) * conv_ch * 2  # bf16 conv history
        state = mc.n_heads * mc.d_state * mc.head_dim * 4  # fp32 SSM state
        return conv + state + 4

    def flops(self, cfg, L: int) -> float:
        mc = self.make_config(cfg)
        D, di = mc.d_model, mc.d_inner
        G, N, H, P = mc.n_groups, mc.d_state, mc.n_heads, mc.head_dim
        Q = min(mc.chunk, L)
        d_in = 2 * di + 2 * G * N + H
        conv_ch = di + 2 * G * N
        proj = D * d_in + di * D
        conv = conv_ch * mc.conv_width
        # chunked scan: intra-chunk scores/outputs + inter-chunk state
        scan = Q * H * N + Q * H * P + 2 * H * N * P
        return 2.0 * L * (proj + conv + scan)
