"""Full language model: embedding → scan-stacked block groups → norm →
logits, plus the canonical ``train_step``-facing loss and the decode step.

Depth is executed as ``lax.scan`` over ``n_groups`` stacked parameter
groups (HLO size is depth-independent — required to compile 80-layer 72B
configs quickly) with per-group ``jax.checkpoint`` (remat) during training.

Modality frontends (VLM/audio archs) are stubs per the assignment: the
first ``frontend_len`` positions take precomputed patch/frame embeddings
supplied by ``input_specs()`` instead of token embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard
from repro.models import blocks as B
from repro.models.layers import apply_norm, embed, init_embedding, init_norm, unembed
from repro.models.mixer_api import DEFAULT_CONTEXT, ApplyContext

IGNORE = -1  # label id excluded from the loss

# name → jax.checkpoint policy for the per-group remat of the standard path
_REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _mesh_scope(ctx: ApplyContext):
    """Honor ``ctx.mesh`` as an override of the ambient mesh: inside the
    scope, every ``shard`` constraint resolves against it."""
    import contextlib

    from repro.distributed import ctx as dctx

    return dctx.use_mesh(ctx.mesh) if ctx.mesh is not None else (
        contextlib.nullcontext()
    )


def tail_mixers(cfg: ModelConfig) -> Tuple[str, ...]:
    return cfg.pattern[: cfg.n_layers % len(cfg.pattern)]


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    plen = len(cfg.pattern)
    n_groups = cfg.n_layers // plen
    k_emb, k_head, k_tail, *k_groups = jax.random.split(key, 3 + plen)
    params: Dict[str, Any] = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
        "groups": [],
    }
    for p, mixer in enumerate(cfg.pattern):
        keys = jax.random.split(k_groups[p], n_groups)
        stacked = jax.vmap(lambda k: B.init_block(k, cfg, mixer))(keys)
        params["groups"].append(stacked)
    tails = tail_mixers(cfg)
    if tails:
        params["tail"] = [
            B.init_block(jax.random.fold_in(k_tail, i), cfg, m)
            for i, m in enumerate(tails)
        ]
    if not cfg.tie_embeddings:
        import math

        params["head"] = {
            "w": Ax(
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
                / math.sqrt(cfg.d_model),
                ("embed", "vocab"),
            )
        }
    return params


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, L) int32
    frontend_embeds: Optional[jax.Array] = None,  # (B, P, D)
    *,
    ctx: Optional[ApplyContext] = None,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (logits (B, L, V), aux losses).

    Execution options — remat(+policy), conv-backend override, layer-loop
    unrolling, decode position offset, mesh override — arrive in one
    ``ApplyContext`` instead of per-call kwargs (DESIGN.md §3).
    """
    ctx = ctx or DEFAULT_CONTEXT
    if ctx.mesh is not None:  # re-enter with ctx.mesh as the ambient mesh
        with _mesh_scope(ctx):
            return forward(
                params, cfg, tokens, frontend_embeds,
                ctx=dataclasses.replace(ctx, mesh=None),
                compute_dtype=compute_dtype,
            )
    # the sequence axis of the residual stream: 'model' (Megatron-SP)
    # unless the context names a dedicated context-parallel axis
    seq_axis = getattr(ctx, "cp_axis", None) or "model"
    cp_on = getattr(ctx, "cp_axis", None) is not None
    # under cp the token batch itself is sequence-sharded end to end
    tokens = shard(tokens, "data", seq_axis if cp_on else None)
    x = embed(params["embed"], tokens, dtype=compute_dtype)
    if frontend_embeds is not None and cfg.frontend_len:
        P = frontend_embeds.shape[1]
        x = jax.lax.dynamic_update_slice(
            x, frontend_embeds.astype(x.dtype), (0, 0, 0)
        )
    x = shard(x, "data", seq_axis if cp_on else None, None)
    plen = len(cfg.pattern)

    def group_body(x, group_params):
        # residual stream sequence-sharded over the seq axis between layers
        # (Megatron-SP): the scan carry (remat save point) is 1/TP the size
        # — required to fit 80-layer remat at 16 rows × 4K tokens per chip.
        x = shard(x, "data", seq_axis, None)
        aux_sum = jnp.zeros((2,), jnp.float32)
        for p, mixer in enumerate(cfg.pattern):
            x, aux = B.apply_block(group_params[p], cfg, mixer, x, ctx)
            if aux:
                aux_sum = aux_sum + jnp.stack(
                    [aux["moe_load_balance"], aux["moe_z_loss"]]
                )
        x = shard(x, "data", seq_axis, None)
        return x, aux_sum

    if getattr(ctx, "reversible", False):
        # Reversible dual-stream substrate (DESIGN.md §15): the scan-level
        # custom_vjp reconstructs activations in backward, so remat is
        # deliberately NOT applied here — the VJP already dictates the
        # (O(1)-in-depth) save set.  Training-only: prefill/decode below
        # never consult this flag.
        from repro.models import reversible as REV

        x, aux_stack = REV.reversible_forward(cfg, ctx, params["groups"], x)
    elif ctx.unroll:
        body = group_body
        if ctx.remat:
            body = jax.checkpoint(group_body, policy=_REMAT_POLICIES[ctx.remat_policy])
        aux_list = []
        n_groups = cfg.n_layers // len(cfg.pattern)
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], tuple(params["groups"]))
            x, a = body(x, gp)
            aux_list.append(a)
        aux_stack = jnp.stack(aux_list) if aux_list else jnp.zeros((1, 2))
    else:
        body = group_body
        if ctx.remat:
            body = jax.checkpoint(group_body, policy=_REMAT_POLICIES[ctx.remat_policy])
        x, aux_stack = jax.lax.scan(
            lambda carry, gp: body(carry, gp), x, tuple(params["groups"])
        )
    aux = {
        "moe_load_balance": jnp.sum(aux_stack[:, 0]),
        "moe_z_loss": jnp.sum(aux_stack[:, 1]),
    }
    for i, mixer in enumerate(tail_mixers(cfg)):
        x, taux = B.apply_block(params["tail"][i], cfg, mixer, x, ctx)
        for k, v in taux.items():
            aux[k] = aux[k] + v
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["head"]["w"].astype(x.dtype)
    # sequence-sharded logits: full-vocab rows live on one chip, so the loss
    # never materializes a vocab-sharded softmax nor a full (B, L, V) fp32.
    # (Under cp the loss reductions over the sharded L dim are plain jnp
    # sums — GSPMD inserts the psum over the cp axis.)
    logits = shard(logits, "data", seq_axis, None)
    return logits, aux


TRAIN_CONTEXT = ApplyContext(remat=True)


def loss_fn(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, L)
    labels: jax.Array,  # (B, L), IGNORE = masked
    frontend_embeds: Optional[jax.Array] = None,
    *,
    ctx: Optional[ApplyContext] = None,
    moe_aux_weight: float = 0.01,
    z_loss_weight: float = 1e-4,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(
        params, cfg, tokens, frontend_embeds,
        ctx=ctx or TRAIN_CONTEXT, compute_dtype=compute_dtype,
    )
    logits = logits.astype(jnp.float32)
    mask = (labels != IGNORE).astype(jnp.float32)
    safe_labels = jnp.where(labels == IGNORE, 0, labels)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    zl = jnp.sum(jnp.square(logz) * mask) / denom
    total = loss + z_loss_weight * zl
    if cfg.moe:
        total = total + moe_aux_weight * (
            aux["moe_load_balance"] + aux["moe_z_loss"]
        )
    metrics = {
        "loss": loss,
        "z_loss": zl,
        "total_loss": total,
        "tokens": jnp.sum(mask),
        **aux,
    }
    return total, metrics


# ----------------------------------------------------------------- decode

def prefill(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, L) prompt
    max_len: int,
    frontend_embeds: Optional[jax.Array] = None,
    dtype=jnp.bfloat16,
    compute_dtype=None,
    *,
    ctx: Optional[ApplyContext] = None,
) -> Tuple[jax.Array, Any]:
    """Prompt forward pass returning (logits (B, L, V), populated caches).
    compute_dtype defaults to the cache dtype."""
    ctx = ctx or DEFAULT_CONTEXT
    if ctx.mesh is not None:
        with _mesh_scope(ctx):
            return prefill(
                params, cfg, tokens, max_len, frontend_embeds, dtype,
                compute_dtype, ctx=dataclasses.replace(ctx, mesh=None),
            )
    compute_dtype = compute_dtype or dtype
    x = embed(params["embed"], tokens, dtype=compute_dtype)
    if frontend_embeds is not None and cfg.frontend_len:
        x = jax.lax.dynamic_update_slice(
            x, frontend_embeds.astype(x.dtype), (0, 0, 0)
        )

    def group_body(x, group_params):
        caches = []
        for p, mixer in enumerate(cfg.pattern):
            x, c = B.block_prefill(
                group_params[p], cfg, mixer, x, max_len, dtype, ctx
            )
            caches.append(c)
        return x, tuple(caches)

    x, group_caches = jax.lax.scan(group_body, x, tuple(params["groups"]))
    caches = {"groups": list(group_caches)}
    tails = tail_mixers(cfg)
    if tails:
        tail_caches = []
        for i, mixer in enumerate(tails):
            x, c = B.block_prefill(
                params["tail"][i], cfg, mixer, x, max_len, dtype, ctx
            )
            tail_caches.append(c)
        caches["tail"] = tail_caches
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["head"]["w"].astype(x.dtype)
    return logits.astype(jnp.float32), caches


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    plen = len(cfg.pattern)
    n_groups = cfg.n_layers // plen
    groups = []
    for mixer in cfg.pattern:
        one = B.init_block_cache(cfg, mixer, batch, max_len, dtype)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype), one
        )
        groups.append(stacked)
    caches = {"groups": groups}
    tails = tail_mixers(cfg)
    if tails:
        caches["tail"] = [
            B.init_block_cache(cfg, m, batch, max_len, dtype) for m in tails
        ]
    return caches


# ----------------------------------------------------- cache slot pooling
#
# Continuous-batching serving (repro.serve) keeps ONE pooled cache tree
# whose batch dim is a fixed pool of request slots.  The helpers below lift
# the per-mixer slot contract (TokenMixer.cache_slot_axes / cache_slice /
# cache_insert / cache_reset) over the full LM cache structure
# ``{"groups": [stacked per-pattern trees], "tail": [per-layer trees]}`` —
# group caches are lax.scan-stacked, so their slot axis is the mixer's
# axis + 1.  All ops are pure functions of (pool, slot) and jit-compatible
# (``slot`` may be traced).


def cache_slot_axes(cfg: ModelConfig, caches) -> Dict[str, Any]:
    """Pytree of ints matching ``caches``: slot axis per leaf, -1 = shared
    across slots (e.g. hyena's decode filter taps)."""
    from repro.models.mixer_api import get_mixer

    def axes_for(mixer: str, cache, shift: int):
        m = get_mixer(mixer)
        spec = m.cache_slot_axes(m.make_config(cfg))
        return {
            k: (-1 if spec.get(k, 0) < 0 else spec.get(k, 0) + shift)
            for k in cache
        }

    axes: Dict[str, Any] = {
        "groups": [
            axes_for(mx, caches["groups"][p], 1)
            for p, mx in enumerate(cfg.pattern)
        ]
    }
    if "tail" in caches:
        axes["tail"] = [
            axes_for(mx, caches["tail"][i], 0)
            for i, mx in enumerate(tail_mixers(cfg))
        ]
    return axes


def cache_page_axes(cfg: ModelConfig, caches) -> Dict[str, Any]:
    """Pytree of ints matching ``caches``: the append-only time axis per
    leaf from each mixer's ``cache_page_axes`` spec, or -1 for pinned
    leaves (bounded state the paged allocator keeps dense).  Scan-stacked
    group caches shift the axis by one, like ``cache_slot_axes``.

    Validates the paging contract here, once per tree: a paged leaf's time
    axis must sit immediately after its slot axis (the block gather/scatter
    moves slot->blocks and time->page as one adjacent pair)."""
    from repro.models.mixer_api import get_mixer

    def axes_for(mixer: str, cache, shift: int):
        m = get_mixer(mixer)
        mc = m.make_config(cfg)
        spec = m.cache_page_axes(mc)
        slots = m.cache_slot_axes(mc)
        for k, ax in spec.items():
            if k not in cache:
                raise ValueError(
                    f"mixer '{mixer}' cache_page_axes names '{k}' but the "
                    f"cache has keys {sorted(cache)}"
                )
            if ax != slots.get(k, 0) + 1:
                raise ValueError(
                    f"mixer '{mixer}' leaf '{k}': paged time axis {ax} "
                    f"must be slot axis {slots.get(k, 0)} + 1"
                )
        return {
            k: (spec[k] + shift if k in spec else -1) for k in cache
        }

    axes: Dict[str, Any] = {
        "groups": [
            axes_for(mx, caches["groups"][p], 1)
            for p, mx in enumerate(cfg.pattern)
        ]
    }
    if "tail" in caches:
        axes["tail"] = [
            axes_for(mx, caches["tail"][i], 0)
            for i, mx in enumerate(tail_mixers(cfg))
        ]
    return axes


def cache_shard_axes(cfg: ModelConfig, caches) -> Dict[str, Any]:
    """Pytree of logical-axes tuples (or None = replicate) matching
    ``caches``, collected from each mixer's ``cache_shard_axes`` spec.

    The spec describes the *unstacked* per-layer leaf; scan-stacked group
    caches carry one extra leading dim, which the rule engine treats as a
    replicated stack dim (same convention as scan-stacked params)."""
    from repro.models.mixer_api import get_mixer

    def axes_for(mixer: str, cache):
        m = get_mixer(mixer)
        spec = m.cache_shard_axes(m.make_config(cfg))
        return {k: spec.get(k) for k in cache}

    axes: Dict[str, Any] = {
        "groups": [
            axes_for(mx, caches["groups"][p])
            for p, mx in enumerate(cfg.pattern)
        ]
    }
    if "tail" in caches:
        axes["tail"] = [
            axes_for(mx, caches["tail"][i])
            for i, mx in enumerate(tail_mixers(cfg))
        ]
    return axes


def cache_shardings(cfg: ModelConfig, caches, mesh, *, fsdp: bool = False,
                    data_axes: Tuple[str, ...] = ("data",)):
    """Rule-driven NamedShardings for a decode-cache tree (works on value
    trees and on ShapeDtypeStruct trees alike): model-axis-sharded
    heads/channels, replicated cursors — DESIGN.md §9."""
    from repro.distributed.sharding import tree_shardings

    return tree_shardings(
        cache_shard_axes(cfg, caches), caches, mesh,
        fsdp=fsdp, data_axes=data_axes,
    )


def make_slot_pool(cfg: ModelConfig, one_cache, n_slots: int):
    """Expand a single-request cache (e.g. the first prefill's, batch 1)
    into an ``n_slots``-wide zeroed pool; shared leaves keep one copy.

    Shared leaves are *copied*, not aliased: the pool is buffer-donated
    through every jitted update, and the very first insert passes the same
    prefill cache as a non-donated argument — donating a buffer that is
    simultaneously another live input is illegal on GPU/TPU.
    """
    axes = cache_slot_axes(cfg, one_cache)

    def expand(ax, leaf):
        if ax < 0:
            return jnp.array(leaf)  # fresh buffer (donation-safe)
        shape = list(leaf.shape)
        shape[ax] = n_slots
        return jnp.zeros(tuple(shape), leaf.dtype)

    return jax.tree_util.tree_map(expand, axes, one_cache)


def slot_insert(cfg: ModelConfig, caches, slot, one):
    """Scatter a batch-1 cache (fresh prefill) into ``slot`` of the pool.
    Shared leaves take the incoming value (identical for every request)."""
    from repro.models.mixer_api import slot_insert_leaf

    axes = cache_slot_axes(cfg, caches)
    return jax.tree_util.tree_map(
        lambda ax, pool, new: slot_insert_leaf(pool, new, slot, ax),
        axes, caches, one,
    )


def slot_reset(cfg: ModelConfig, caches, slot):
    """Zero one slot across every per-slot leaf — pure function, so an
    evicted request's state cannot leak into the slot's next occupant."""
    from repro.models.mixer_api import slot_zero_leaf

    axes = cache_slot_axes(cfg, caches)
    return jax.tree_util.tree_map(
        lambda ax, leaf: slot_zero_leaf(leaf, slot, ax), axes, caches
    )


def mask_slots(cfg: ModelConfig, new_caches, old_caches, active: jax.Array):
    """Slot-masked cache update: keep ``new`` where ``active`` (bool (S,)),
    freeze ``old`` elsewhere.  Applied after a pooled decode step so free
    slots hold exactly their reset state (scheduler invariant I3)."""
    axes = cache_slot_axes(cfg, new_caches)

    def pick(ax, new, old):
        if ax < 0:
            return new
        shape = [1] * new.ndim
        shape[ax] = active.shape[0]
        return jnp.where(active.reshape(shape), new, old)

    return jax.tree_util.tree_map(pick, axes, new_caches, old_caches)


def decode_step(
    params, cfg: ModelConfig, token_t: jax.Array, caches,
    compute_dtype=jnp.bfloat16, *, ctx: Optional[ApplyContext] = None,
) -> Tuple[jax.Array, Any]:
    """One decode step: token_t (B,) int32 -> (logits (B, V), new caches)."""
    ctx = ctx or DEFAULT_CONTEXT
    if ctx.mesh is not None:
        with _mesh_scope(ctx):
            return decode_step(
                params, cfg, token_t, caches, compute_dtype,
                ctx=dataclasses.replace(ctx, mesh=None),
            )
    unroll = ctx.unroll
    x = embed(params["embed"], token_t[:, None], dtype=compute_dtype)[:, 0]  # (B, D)
    x = shard(x, "data", None)

    def group_body(x, xs):
        group_params, cache = xs
        new_caches = []
        for p, mixer in enumerate(cfg.pattern):
            x, c = B.block_decode(group_params[p], cfg, mixer, x, cache[p])
            new_caches.append(c)
        return x, tuple(new_caches)

    if unroll:
        n_groups = cfg.n_layers // len(cfg.pattern)
        outs = []
        for g in range(n_groups):
            take = lambda t: jax.tree_util.tree_map(lambda a: a[g], t)
            x, cs = group_body(
                x, (take(tuple(params["groups"])), take(tuple(caches["groups"])))
            )
            outs.append(cs)
        new_groups = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs
        ) if outs else ()
    else:
        x, new_groups = jax.lax.scan(
            group_body, x, (tuple(params["groups"]), tuple(caches["groups"]))
        )
    out_caches = {"groups": list(new_groups)}
    tails = tail_mixers(cfg)
    if tails:
        new_tail = []
        for i, mixer in enumerate(tails):
            x, c = B.block_decode(params["tail"][i], cfg, mixer, x, caches["tail"][i])
            new_tail.append(c)
        out_caches["tail"] = new_tail
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["head"]["w"].astype(x.dtype)
    logits = shard(logits, "data", "model")
    return logits.astype(jnp.float32), out_caches
