"""Shared layers: norms, channel-MLP variants, embeddings, RoPE."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.param import Ax


# ------------------------------------------------------------------ norms

def init_norm(d: int, kind: str = "rmsnorm") -> Dict[str, Any]:
    if kind == "rmsnorm":
        return {"g": Ax(jnp.zeros((d,), jnp.float32), ("embed",))}
    if kind == "layernorm":
        return {
            "g": Ax(jnp.zeros((d,), jnp.float32), ("embed",)),
            "b": Ax(jnp.zeros((d,), jnp.float32), ("embed",)),
        }
    raise ValueError(kind)


def apply_norm(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * (1.0 + params["g"])
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps) * (1.0 + params["g"]) + params["b"]
    return y.astype(x.dtype)


# ------------------------------------------------------------------ dense

def init_dense(key, d_in, d_out, axes=("embed", "mlp"), bias=False, scale=None):
    import math

    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": Ax(scale * jax.random.normal(key, (d_in, d_out), jnp.float32), axes)}
    if bias:
        p["b"] = Ax(jnp.zeros((d_out,), jnp.float32), (axes[1],))
    return p


def dense(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# -------------------------------------------------------------------- MLP

def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu") -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "up": init_dense(k1, d_model, d_ff, ("embed", "mlp")),
            "gate": init_dense(k2, d_model, d_ff, ("embed", "mlp")),
            "down": init_dense(k3, d_ff, d_model, ("mlp", "embed")),
        }
    # gelu / squared_relu: plain 2-layer
    return {
        "up": init_dense(k1, d_model, d_ff, ("embed", "mlp")),
        "down": init_dense(k3, d_ff, d_model, ("mlp", "embed")),
    }


def apply_mlp(params, x, kind: str = "swiglu"):
    from repro.distributed.ctx import shard

    if kind == "swiglu":
        h = jax.nn.silu(dense(params["gate"], x)) * dense(params["up"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(dense(params["gate"], x)) * dense(params["up"], x)
    elif kind == "gelu":
        h = jax.nn.gelu(dense(params["up"], x))
    elif kind == "squared_relu":  # Nemotron-4 (Primer)
        h = jnp.square(jax.nn.relu(dense(params["up"], x)))
    else:
        raise ValueError(kind)
    h = shard(h, "data", *([None] * (h.ndim - 2)), "model")
    return dense(params["down"], h)


# -------------------------------------------------------------- embedding

def init_embedding(key, vocab: int, d_model: int):
    return {
        "table": Ax(
            0.02 * jax.random.normal(key, (vocab, d_model), jnp.float32),
            ("vocab", "embed"),
        )
    }


def embed(params, tokens, dtype=jnp.bfloat16):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x):
    return x @ params["table"].astype(x.dtype).T


# ------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (B, L, H, Dh), positions: (L,) or (B, L)."""
    B, L, H, Dh = x.shape
    freqs = rope_freqs(Dh, theta)  # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, L, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
