"""Hyena-ViT: the paper's §4.5 vision experiment — drop the attention
operator out of a ViT and drop the (bidirectional, non-causal) Hyena
operator in, unchanged from its language form except causality.

We keep the language Hyena operator and simply evaluate the long conv
non-causally (circular FFT conv without the causal zero-pad masking would
leak; instead we center the filter by rolling — the standard ViT-Hyena
trick of treating the patch grid as a sequence).  Class-token-free: global
average pooling (as in the paper: "remove the class token and positional
embeddings, similar to S4ND").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.core import filters as F
from repro.core.operator import HyenaConfig
from repro.models.hyena import apply_hyena_mixer, init_hyena_mixer
from repro.models.layers import apply_mlp, apply_norm, init_dense, init_mlp, init_norm


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image_size: int = 32
    patch_size: int = 4
    d_model: int = 128
    n_layers: int = 4
    d_ff: int = 256
    n_classes: int = 10
    hyena_order: int = 2
    channels: int = 3

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def patch_dim(self) -> int:
        return self.channels * self.patch_size ** 2


def _hyena_cfg(cfg: ViTConfig) -> HyenaConfig:
    return HyenaConfig(
        d_model=cfg.d_model,
        order=cfg.hyena_order,
        filter=F.FilterConfig(
            d_model=cfg.d_model, order=cfg.hyena_order, ffn_width=32, pos_dim=17
        ),
    )


def init_vit(key, cfg: ViTConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 2)
    params: Dict[str, Any] = {
        "patch": init_dense(ks[0], cfg.patch_dim, cfg.d_model, ("embed", "embed")),
        "blocks": [],
        "final_norm": init_norm(cfg.d_model),
        "head": init_dense(ks[1], cfg.d_model, cfg.n_classes, ("embed", None)),
    }
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[2 + i])
        params["blocks"].append(
            {
                "norm1": init_norm(cfg.d_model),
                "mixer": init_hyena_mixer(k1, _hyena_cfg(cfg)),
                "norm2": init_norm(cfg.d_model),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu"),
            }
        )
    return params


def patchify(cfg: ViTConfig, images: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B, n_patches, patch_dim)."""
    B, H, W, C = images.shape
    p = cfg.patch_size
    x = images.reshape(B, H // p, p, W // p, p, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, cfg.n_patches, cfg.patch_dim)
    return x


def apply_vit(params, cfg: ViTConfig, images: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B, n_classes) logits."""
    x = patchify(cfg, images)
    x = x @ params["patch"]["w"].astype(x.dtype)
    hcfg = _hyena_cfg(cfg)
    for blk in params["blocks"]:
        h = apply_norm(blk["norm1"], x)
        h = apply_hyena_mixer(blk["mixer"], hcfg, h)
        x = x + h
        h = apply_norm(blk["norm2"], x)
        x = x + apply_mlp(blk["mlp"], h, "gelu")
    x = apply_norm(params["final_norm"], x)
    x = jnp.mean(x, axis=1)  # GAP, no class token (paper A.4)
    return x @ params["head"]["w"].astype(x.dtype)


def vit_loss(params, cfg: ViTConfig, images, labels):
    logits = apply_vit(params, cfg, images).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}
