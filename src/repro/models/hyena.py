"""Hyena as an LM token mixer — the paper's drop-in attention replacement.

Thin adapter over :mod:`repro.core.operator` adding activation-sharding
constraints: Hyena's long conv is depthwise, so tensor parallelism over the
channel dim is collective-free inside the operator (DESIGN.md §5); the only
TP collectives are the in/out projections' (same as Megatron attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import filters as F
from repro.core.fftconv import fft_causal_conv, short_causal_conv
from repro.core.operator import (
    HyenaConfig,
    hyena_decode_step,
    init_decode_cache,
    init_hyena,
    precompute_decode_filters,
)
from repro.distributed.ctx import shard


def init_hyena_mixer(key, cfg: HyenaConfig) -> Dict[str, Any]:
    return init_hyena(key, cfg)


def apply_hyena_mixer(
    params, cfg: HyenaConfig, x: jax.Array, *, pos_offset: int = 0,
    conv_backend: Optional[str] = None,
) -> jax.Array:
    """(B, L, D) -> (B, L, D), TP over channels.

    The input arrives sequence-sharded (residual-stream layout); keeping the
    in_proj output sequence-sharded (weights gathered — MBs) and moving to
    the channel-sharded conv layout with per-tensor all-to-alls is 16× less
    traffic than all-gathering the activation (GBs) — §Perf pair A iter 3.
    """
    B, L, D = x.shape
    N = cfg.order
    z = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(x.dtype)
    z = shard(z, "data", "model", None)  # seq-sharded; short conv halo-exchanges
    z = short_causal_conv(z, params["short_filter"])
    parts = jnp.split(z, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    # conv layout: channels on model, full sequence (all-to-all, not gather)
    v = shard(v, "data", None, "model")
    xs = [shard(xn, "data", None, "model") for xn in xs]
    h = F.evaluate_filters(params["filters"], cfg.filter, L)  # (N, D, L)
    skip = F.filter_skip(params["filters"], cfg.filter)
    backend = conv_backend or cfg.conv_backend
    for n in range(N):
        hn = shard(h[n], "model", None)  # depthwise: channel-sharded filter
        if backend == "toeplitz":
            from repro.kernels import ops as kops

            conv = kops.toeplitz_conv(v, hn, skip[n])
        elif backend == "blockfft":
            from repro.core.blockfft import blockfft_causal_conv

            conv = blockfft_causal_conv(v, hn, skip[n])
        elif backend == "fft_local":  # single-device / oracle path
            conv = fft_causal_conv(v, hn, skip[n])
        else:  # "fft": shard_map-forced per-chip FFT under a mesh
            from repro.core.fftconv import fft_causal_conv_sharded

            conv = fft_causal_conv_sharded(v, hn, skip[n])
        v = xs[n] * conv.astype(x.dtype)
        v = shard(v, "data", None, "model")
    y = v @ params["out_proj"]["w"].astype(x.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(x.dtype)
    return y


def init_hyena_cache(cfg: HyenaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return init_decode_cache(cfg, batch, max_len, dtype)


def hyena_mixer_decode(params, cfg: HyenaConfig, x_t, cache):
    return hyena_decode_step(params, cfg, x_t, cache)


def hyena_prefill(
    params, cfg: HyenaConfig, x: jax.Array, max_len: int, dtype=jnp.bfloat16,
    *, pos_offset: int = 0,
) -> Tuple[jax.Array, dict]:
    """Full-sequence forward capturing the decode caches: the short-conv
    input history and, per order, the conv *operand* history (newest-first),
    which is exactly what ``conv_cache_step`` dots against at decode time."""
    B, L, D = x.shape
    N = cfg.order
    z_pre = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z_pre = z_pre + params["in_proj"]["b"].astype(x.dtype)
    z = short_causal_conv(z_pre, params["short_filter"])
    parts = jnp.split(z, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    # decode filters are evaluated on the max_len grid so taps match the
    # decode-time dot exactly
    h_dec = F.evaluate_filters(params["filters"], cfg.filter, max_len)
    skip = F.filter_skip(params["filters"], cfg.filter)
    cache = init_decode_cache(cfg, B, max_len, dtype)

    def hist(seq):  # (B, L, D) -> newest-first (B, max_len, D)
        n = min(L, max_len)
        recent = jnp.flip(seq[:, L - n :], axis=1).astype(dtype)
        pad = max_len - n
        return jnp.pad(recent, ((0, 0), (0, pad), (0, 0)))

    Ks = cfg.short_filter_len - 1
    short_hist = hist(z_pre)[:, :Ks]
    longs = []
    for n in range(N):
        longs.append(hist(v))
        conv = fft_causal_conv(v, h_dec[n][:, :L], skip[n])
        v = xs[n] * conv.astype(x.dtype)
    y = v @ params["out_proj"]["w"].astype(x.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(x.dtype)
    cache = dict(cache)
    cache.update({
        "short": short_hist,
        "long": jnp.stack(longs),
        "t": jnp.asarray(L, jnp.int32),
        "h": h_dec,
        "skip": skip,
    })
    return y, cache
