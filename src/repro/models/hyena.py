"""Hyena as an LM token mixer — the paper's drop-in attention replacement.

Thin adapter over :mod:`repro.core.operator` adding activation-sharding
constraints: Hyena's long conv is depthwise, so tensor parallelism over the
channel dim is collective-free inside the operator (DESIGN.md §6); the only
TP collectives are the in/out projections' (same as Megatron attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import filters as F
from repro.core.conv_api import get_conv_backend
from repro.core.fftconv import short_causal_conv
from repro.core.operator import (
    HyenaConfig,
    hyena_decode_step,
    init_decode_cache,
    init_hyena,
    precompute_decode_filters,
)
from repro.distributed.ctx import shard
from repro.models.mixer_api import (
    DEFAULT_CONTEXT,
    ApplyContext,
    TokenMixer,
    register_mixer,
)


def init_hyena_mixer(key, cfg: HyenaConfig) -> Dict[str, Any]:
    return init_hyena(key, cfg)


def apply_hyena_mixer(
    params, cfg: HyenaConfig, x: jax.Array, ctx: Optional[ApplyContext] = None
) -> jax.Array:
    """(B, L, D) -> (B, L, D), TP over channels.

    The input arrives sequence-sharded (residual-stream layout); keeping the
    in_proj output sequence-sharded (weights gathered — MBs) and moving to
    the channel-sharded conv layout with per-tensor all-to-alls is 16× less
    traffic than all-gathering the activation (GBs) — §Perf pair A iter 3.
    """
    ctx = ctx or DEFAULT_CONTEXT
    B, L, D = x.shape
    N = cfg.order
    cp = getattr(ctx, "cp_axis", None)
    seq_axis = cp or "model"
    z = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(x.dtype)
    z = shard(z, "data", seq_axis, None)  # seq-sharded; short conv halo-exchanges
    z = short_causal_conv(z, params["short_filter"])
    parts = jnp.split(z, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    if cp is not None:
        # context parallelism: the sequence dim STAYS sharded through the
        # sequence-parallel conv — the channel all-to-all layout below
        # would put the full L on every chip, exactly what cp must avoid
        v = shard(v, "data", cp, None)
        xs = [shard(xn, "data", cp, None) for xn in xs]
    else:
        # conv layout: channels on model, full sequence (all-to-all, not gather)
        v = shard(v, "data", None, "model")
        xs = [shard(xn, "data", None, "model") for xn in xs]
    h = F.evaluate_filters(params["filters"], cfg.filter, L)  # (N, D, L)
    skip = F.filter_skip(params["filters"], cfg.filter)
    # length-aware routing: an ExecutionContext steers long sequences onto
    # the sequence-parallel fft_sp backend past its per-mesh threshold
    # (and cp training routes there unconditionally)
    backend = get_conv_backend(ctx.conv_backend_for(L))
    backend.validate_len(L)
    for n in range(N):
        # depthwise: channel-sharded filter in the TP layout; under cp the
        # taps stay replicated and fft_sp scatters their L dim itself
        hn = h[n] if cp is not None else shard(h[n], "model", None)
        # gate fused into the conv backend (xs[n] shares v's sharding, so
        # the fused multiply stays collective-free)
        v = backend(v, hn, skip[n], gate=xs[n]).astype(x.dtype)
        v = shard(v, "data", cp, None) if cp is not None else shard(
            v, "data", None, "model"
        )
    y = v @ params["out_proj"]["w"].astype(x.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(x.dtype)
    return y


def init_hyena_cache(cfg: HyenaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return init_decode_cache(cfg, batch, max_len, dtype)


def hyena_mixer_decode(params, cfg: HyenaConfig, x_t, cache):
    return hyena_decode_step(params, cfg, x_t, cache)


def hyena_prefill(
    params, cfg: HyenaConfig, x: jax.Array, max_len: int, dtype=jnp.bfloat16,
    *, conv_backend: Optional[str] = None,
) -> Tuple[jax.Array, dict]:
    """Full-sequence forward capturing the decode caches: the short-conv
    input history (newest-first rolling window) and, per order, the conv
    *operand* history at absolute positions (token ``p`` at index ``p``,
    append-only) — exactly what ``hyena_decode_step``'s stacked history
    einsum contracts against at decode time.

    The prompt's long convs run on the ``conv_backend`` registration
    (default ``fft``); decode steps themselves are cached dots and have no
    backend dimension."""
    backend = get_conv_backend(conv_backend)
    B, L, D = x.shape
    backend.validate_len(L)
    N = cfg.order
    z_pre = x @ params["in_proj"]["w"].astype(x.dtype)
    if "b" in params["in_proj"]:
        z_pre = z_pre + params["in_proj"]["b"].astype(x.dtype)
    z = short_causal_conv(z_pre, params["short_filter"])
    parts = jnp.split(z, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    # decode filters are evaluated on the max_len grid so taps match the
    # decode-time dot exactly
    h_dec = F.evaluate_filters(params["filters"], cfg.filter, max_len)
    skip = F.filter_skip(params["filters"], cfg.filter)
    cache = init_decode_cache(cfg, B, max_len, dtype)

    def hist(seq):  # (B, L, D) -> absolute positions, zero past L
        # (prompts longer than max_len keep their last max_len values,
        # re-based to position 0 — decoding past max_len is out of
        # contract either way)
        n = min(L, max_len)
        recent = seq[:, L - n :].astype(dtype)
        return jnp.pad(recent, ((0, 0), (0, max_len - n), (0, 0)))

    def newest_first(seq, k):  # (B, L, D) -> (B, k, D) rolling window
        n = min(L, k)
        recent = jnp.flip(seq[:, L - n :], axis=1).astype(dtype)
        return jnp.pad(recent, ((0, 0), (0, k - n), (0, 0)))

    Ks = cfg.short_filter_len - 1
    short_hist = newest_first(z_pre, Ks)
    longs = []
    for n in range(N):
        longs.append(hist(v))
        v = backend(v, h_dec[n][:, :L], skip[n], gate=xs[n]).astype(x.dtype)
    y = v @ params["out_proj"]["w"].astype(x.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(x.dtype)
    cache = dict(cache)
    cache.update({
        "short": short_hist,
        "long": jnp.stack(longs),
        "t": jnp.full((B,), L, jnp.int32),
        "h": h_dec,
        "skip": skip,
    })
    return y, cache


# ----------------------------------------------------------- registration

@register_mixer
class HyenaMixer(TokenMixer):
    """The paper's operator as a drop-in token mixer (Def. 3.1)."""

    name = "hyena"
    attention_free = True
    subquadratic = True

    def make_config(self, cfg) -> HyenaConfig:
        return HyenaConfig(
            d_model=cfg.d_model,
            order=cfg.hyena_order,
            filter=F.FilterConfig(
                d_model=cfg.d_model,
                order=cfg.hyena_order,
                ffn_width=cfg.hyena_filter_width,
                ffn_depth=cfg.hyena_filter_depth,
                pos_dim=cfg.hyena_pos_dim,
                sine_freq=cfg.hyena_sine_freq,
                decay_fast=cfg.hyena_decay[0],
                decay_slow=cfg.hyena_decay[1],
                max_support=cfg.hyena_max_support,
            ),
        )

    def init(self, key, mc):
        return init_hyena_mixer(key, mc)

    def apply(self, params, mc, h, ctx: ApplyContext):
        return apply_hyena_mixer(params, mc, h, ctx)

    def init_cache(self, mc, batch, max_len, dtype):
        return init_hyena_cache(mc, batch, max_len, dtype)

    def prefill(self, params, mc, h, max_len, dtype, ctx: ApplyContext):
        if ctx.pos_offset:
            # hyena filters are relative-lag functions with no absolute
            # position handle; a chunked prefill would need operand-history
            # stitching, which the cache layout does not support yet.
            raise NotImplementedError(
                "hyena prefill does not support pos_offset != 0"
            )
        return hyena_prefill(
            params, mc, h, max_len, dtype,
            conv_backend=ctx.conv_backend_for(h.shape[1]),
        )

    def decode_step(self, params, mc, h_t, cache):
        return hyena_mixer_decode(params, mc, h_t, cache)

    def cache_slot_axes(self, mc) -> dict:
        # "long" stacks the per-order operand histories ahead of the batch
        # dim; the decode filter taps "h"/"skip" depend only on params and
        # the max_len grid, so the pool shares one copy across slots.
        return {"long": 1, "h": -1, "skip": -1}

    def cache_page_axes(self, mc) -> dict:
        # the per-order operand history is append-only at absolute
        # positions (token p at index p; decode masks taps past the
        # cursor), so it pages exactly like attention KV — the paper's
        # O(L) operand state is the dominant per-request memory.  "short"
        # is a (K-1)-wide rolling window and "t"/"h"/"skip" are O(1) or
        # shared: pinned.
        return {"long": 2}

    def cache_shard_axes(self, mc) -> dict:
        # depthwise conv: every cache leaf's channel dim shards over the
        # model axis collective-free (the decode dot contracts per channel);
        # the operand-history time dim and the slot dim replicate.  "short"
        # holds the (N+1)·D projected-input history — the in_proj output
        # dim — so it reuses the hyena_inner rule.
        return {
            "short": ("cache_slots", None, "hyena_inner"),
            "long": (None, "cache_slots", "kv_seq", "hyena_channels"),
            "h": (None, "hyena_channels", "kv_seq"),
            "skip": (None, "hyena_channels"),
        }

    def state_bytes(self, cfg, max_len: int) -> int:
        mc = self.make_config(cfg)
        D, N = mc.d_model, mc.order
        inner = (N + 1) * D
        short = (mc.short_filter_len - 1) * inner  # projected-input history
        long = N * max_len * D  # per-order conv operand history
        # the serving cache (prefill-populated) also carries the fp32 filter
        # taps on the max_len grid plus the skip gains — batch-independent
        # but resident per layer, and the same magnitude as ``long``
        taps = N * D * max_len + N * D
        return (short + long) * 2 + taps * 4 + 4  # bf16 + fp32 + cursor

    def flops(self, cfg, L: int) -> float:
        """Paper App. A.2 accounting, ×2 for mul+add."""
        import math

        mc = self.make_config(cfg)
        D, N, K = mc.d_model, mc.order, mc.short_filter_len
        fc = mc.filter
        proj = (N + 1) * D * D + D * D  # in_proj + out_proj
        short = (N + 1) * D * K
        fftconv = 5 * N * D * math.log2(max(L, 2))
        # implicit filter FFN evaluated on the length-L grid
        filt = (
            fc.pos_dim * fc.ffn_width
            + (fc.ffn_depth - 1) * fc.ffn_width * fc.ffn_width
            + fc.ffn_width * N * D
        )
        return 2.0 * L * (proj + short + fftconv + filt)
