"""Top-k token-choice MoE channel mixer (Switch/GShard-family, as used by
DBRX 16e/top-4 and Granite 40e/top-8).

Dispatch is rank-based (argsort-free): per-(token, expert) position =
cumulative count of earlier tokens routed to that expert; tokens whose rank
exceeds the capacity are dropped (their combine weight masks to zero —
residual carries them, standard token-dropping behaviour).  This avoids the
O(S·E·C) one-hot dispatch tensor of the classic GShard einsum — memory is
O(S·E) + O(E·C·D), jit/pjit-safe (all shapes static).

Expert weights carry the "experts" logical axis → expert parallelism over
the model mesh axis; the scatter/gather to (E, C, D) buffers becomes XLA
all-to-alls under pjit.  Aux losses: load-balancing (Switch) + router
z-loss, returned for the trainer to consume.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.distributed.ctx import shard
from repro.models.layers import init_dense


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    mlp: str = "swiglu"


def init_moe(key, cfg: MoEConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    import math

    def ew(k, shape, fan_in, axes):
        return Ax(
            jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in), axes
        )

    p = {
        "router": init_dense(ks[0], D, E, ("embed", "experts")),
        "up": ew(ks[1], (E, D, F), D, ("experts", "embed", "expert_ff")),
        "down": ew(ks[2], (E, F, D), F, ("experts", "expert_ff", "embed")),
    }
    if cfg.mlp == "swiglu":
        p["gate"] = ew(ks[3], (E, D, F), D, ("experts", "embed", "expert_ff"))
    return p


def apply_moe(params, cfg: MoEConfig, x: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, L, D) -> (y, aux_losses).

    Grouped (GShard-style) dispatch: each batch row is an independent
    routing group with its own capacity, so the token→buffer scatter never
    crosses the data axis.  The dispatch buffer is (B, E, C, D) with B on
    'data' and E on 'model' — the data→expert hop is the all-to-all XLA
    inserts between those shardings, and expert GEMMs are fully partitioned
    (a global-capacity buffer would be replicated across the data axis and
    make every data shard redundantly compute all experts — the 16×
    useful-FLOPs bug caught by the dry-run roofline; EXPERIMENTS.md §Perf).
    """
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (x @ params["router"]["w"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, L, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (B, L, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    C = int(cfg.capacity_factor * L * K / E) + 1
    # per-row rank of each (token, k) within its expert
    sel = jax.nn.one_hot(gate_idx.reshape(B, L * K), E, dtype=jnp.int32)
    ranks_all = jnp.cumsum(sel, axis=1) - sel  # (B, L*K, E)
    rank = jnp.take_along_axis(
        ranks_all, gate_idx.reshape(B, L * K, 1), axis=2
    ).reshape(B, L, K)
    keep = rank < C
    gate_vals = jnp.where(keep, gate_vals, 0.0)

    # scatter tokens into per-row (E·C, D) buffers (vmapped over B)
    flat_slot = gate_idx * C + jnp.where(keep, rank, C - 1)  # (B, L, K)
    src = jnp.repeat(x[:, :, None, :], K, axis=2).reshape(B, L * K, D)
    src = jnp.where(keep.reshape(B, L * K, 1), src, 0)

    def scatter_row(slots, vals):
        return jnp.zeros((E * C, D), x.dtype).at[slots].add(vals)

    buf = jax.vmap(scatter_row)(flat_slot.reshape(B, L * K), src)
    buf = buf.reshape(B, E, C, D)
    buf = shard(buf, "data", "model", None, None)

    # expert MLPs, batched over (B, E)
    up = jnp.einsum("becd,edf->becf", buf, params["up"].astype(x.dtype))
    if cfg.mlp == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, params["gate"].astype(x.dtype))
        h = jax.nn.silu(g) * up
    elif cfg.mlp == "squared_relu":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    out = jnp.einsum("becf,efd->becd", h, params["down"].astype(x.dtype))
    out = shard(out, "data", "model", None, None)

    # gather back with combine weights (vmapped over B)
    def gather_row(buf_row, slots):
        return buf_row[slots]

    gathered = jax.vmap(gather_row)(
        out.reshape(B, E * C, D), flat_slot.reshape(B, L * K)
    )  # (B, L*K, D)
    gathered = gathered * gate_vals.reshape(B, L * K, 1).astype(x.dtype)
    y = jnp.sum(gathered.reshape(B, L, K, D), axis=2)

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    top1 = jax.nn.one_hot(gate_idx[..., 0], E)
    ce = jnp.mean(jnp.mean(top1, axis=(0, 1)) * E * me)
    load_balance = ce * E
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_load_balance": load_balance, "moe_z_loss": z_loss}
    return y, aux
