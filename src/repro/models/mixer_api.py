"""Pluggable TokenMixer API (DESIGN.md §3).

The paper's headline claim is that Hyena is a *drop-in replacement* for
attention; this module is where that claim is an interface rather than an
if/elif chain.  A :class:`TokenMixer` bundles everything the block/LM/serve
layers need from a mixer:

  * ``make_config(cfg)`` — derive the mixer's own config from ``ModelConfig``
  * ``init(key, mc)`` / ``apply(params, mc, h, ctx)`` — train/prefill forward
  * ``init_cache`` / ``prefill`` / ``decode_step`` — the serving contract
  * capability metadata — ``supports_decode``, ``attention_free``,
    ``subquadratic``, ``state_bytes(cfg, L)``, ``flops(cfg, L)``

plus an :class:`ApplyContext` that replaces the ad-hoc kwarg threading
(``pos_offset`` / ``conv_backend`` / remat policy) through
``lm.loss_fn → blocks → hyena → operator``.

Registering a new mixer is one module + one ``@register_mixer`` — zero
dispatch sites change (``blocks.py`` / ``lm.py`` contain no mixer names).
The registry conformance suite (tests/test_mixer_registry.py) asserts the
shared contract over every registration.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

REMAT_POLICIES = ("nothing", "dots", "dots_no_batch")
REMAT_ENV_VAR = "REPRO_REMAT_POLICY"


def resolve_remat_policy(override: Optional[str] = None) -> str:
    """One resolution point for the remat policy name: explicit ``override``
    > ``$REPRO_REMAT_POLICY`` > ``"nothing"`` — validated, like
    :func:`repro.core.conv_api.resolve_conv_backend` for backends."""
    import os

    name = override or os.environ.get(REMAT_ENV_VAR) or "nothing"
    if name not in REMAT_POLICIES:
        raise ValueError(
            f"unknown remat policy '{name}'; have {REMAT_POLICIES}"
        )
    return name

# modules that self-register their mixers on import; loaded lazily so this
# module stays import-cycle-free (they all import mixer_api back)
_BUILTIN_MODULES = (
    "repro.models.attention",
    "repro.models.hyena",
    "repro.models.hyena_variants",
    "repro.models.ssd",
    "repro.models.rglru",
)


@dataclasses.dataclass(frozen=True)
class ApplyContext:
    """Per-call execution context threaded through the model stack.

    One object replaces the scattered kwargs: decode position offset, the
    long-conv backend override, remat policy, layer-loop unrolling, and an
    optional mesh handle (``None`` = use the ambient
    ``repro.distributed.ctx`` mesh).  Hashable and static — jit closes over
    it, it is never traced.

    Backend strings are validated here, at construction time, so an unknown
    backend raises before any tracing starts — not mid-forward.
    """

    pos_offset: int = 0
    conv_backend: Optional[str] = None  # None -> registry default ("fft")
    remat: bool = False
    remat_policy: str = "nothing"
    unroll: bool = False  # python loop instead of scan (dry-run cost probes)
    mesh: Any = None

    def __post_init__(self):
        if self.conv_backend is not None:
            from repro.core.conv_api import get_conv_backend

            get_conv_backend(self.conv_backend)  # raises with registered list
        if self.remat_policy not in REMAT_POLICIES:
            raise ValueError(
                f"unknown remat policy '{self.remat_policy}'; "
                f"have {REMAT_POLICIES}"
            )

    def conv_backend_for(self, L: int) -> Optional[str]:
        """Long-conv backend for a length-``L`` pass.  The base context has
        no length-dependent routing; ``ExecutionContext``
        (repro.distributed.execution) overrides this to steer long-prompt
        prefill through the sequence-parallel ``fft_sp`` backend when ``L``
        exceeds the per-mesh threshold."""
        return self.conv_backend


DEFAULT_CONTEXT = ApplyContext()


class TokenMixer:
    """Interface + capability metadata for a registered token mixer.

    Subclass, set ``name`` (and capability flags), implement the methods,
    and decorate with :func:`register_mixer`.  ``mc`` below is the object
    returned by ``make_config`` — opaque to every caller.
    """

    name: str = ""
    supports_decode: bool = True
    # capability flags default to the *least* favorable values: a mixer that
    # forgets to set them is treated as quadratic dense attention rather than
    # silently admitted to 500K-token cells (dryrun long_500k gating).
    attention_free: bool = False  # no dense global KV attention matrix
    subquadratic: bool = False  # can run 500K-token decode

    # ------------------------------------------------------------ contract
    def make_config(self, cfg) -> Any:
        """ModelConfig -> mixer config (opaque to callers)."""
        raise NotImplementedError

    def init(self, key, mc) -> Dict[str, Any]:
        raise NotImplementedError

    def apply(self, params, mc, h, ctx: ApplyContext):
        """Full-sequence forward: (B, L, D) -> (B, L, D)."""
        raise NotImplementedError

    def init_cache(self, mc, batch: int, max_len: int, dtype):
        """Empty decode cache, directly consumable by ``decode_step``."""
        raise NotImplementedError

    def prefill(self, params, mc, h, max_len: int, dtype,
                ctx: ApplyContext) -> Tuple[Any, Any]:
        """Full-sequence forward that also returns a populated cache."""
        raise NotImplementedError

    def decode_step(self, params, mc, h_t, cache) -> Tuple[Any, Any]:
        """One token: (B, D) -> (B, D), updated cache (same treedef)."""
        raise NotImplementedError

    # ------------------------------------------------- cache slot contract
    # (leaf ops shared with repro.models.lm's pooled-tree variants live at
    # module level below: slot_slice_leaf / slot_insert_leaf / slot_zero_leaf)
    #
    # Continuous-batching serving keeps one pooled cache whose batch dim is
    # a fixed pool of request *slots*; admission scatters a freshly
    # prefilled single-request cache into a free slot and completion /
    # eviction zeroes it.  The three ops below are derived generically from
    # ``cache_slot_axes`` — a mixer only overrides the spec when a cache
    # leaf's slot dim is not axis 0 (hyena's stacked per-order operand
    # history) or when a leaf is request-independent and shared across the
    # pool (hyena's filter taps).  Decode caches are flat ``str -> array``
    # dicts; the registry conformance suite asserts the spec covers every
    # key produced by ``init_cache`` and ``prefill``.

    def cache_slot_axes(self, mc) -> Dict[str, int]:
        """Slot (batch) axis per cache key.  Missing keys default to axis
        0; ``-1`` marks a leaf shared across slots (never sliced/reset)."""
        return {}

    def cache_page_axes(self, mc) -> Dict[str, int]:
        """Time (sequence-position) axis per cache key for leaves whose
        per-slot state grows with the sequence **append-only**: position
        ``p`` is written once, at index ``p``, and never moved.  These are
        the leaves the paged allocator (``repro.serve.paged``) splits into
        fixed-size blocks behind a copy-on-write block table; a radix
        prefix cache can then share their pages across requests.

        Keys not named here are *pinned*: bounded per-slot state (cursors,
        conv windows, recurrent states, sliding-window KV rings — bounded
        by the window, so paging them buys nothing) kept in a dense pool
        and snapshotted wholesale by the prefix cache.

        Contract (conformance-tested): every named key exists in the
        cache, its time axis is exactly ``cache_slot_axes`` slot axis + 1
        (block gather/scatter relies on the adjacency), its length is the
        ``max_len`` grid, and the mixer's ``decode_step`` must tolerate
        arbitrary garbage at positions ``>= t`` (recycled blocks are not
        re-zeroed before reuse within a view)."""
        return {}

    def cache_shard_axes(self, mc) -> Dict[str, Tuple[Optional[str], ...]]:
        """Logical axis names per cache key, for rule-driven decode-cache
        sharding (DESIGN.md §9): one tuple per key, parallel to the leaf's
        dims (``None`` = no rule for that dim).  Names resolve through the
        same TP rule engine as the parameters
        (``repro.distributed.sharding.TP_RULES``) — head/channel dims land
        on the model axis, ``"cache_slots"`` and cursor dims replicate.
        Keys left out of the spec are fully replicated; the conformance
        suite asserts every named key exists in the cache with a matching
        rank."""
        return {}

    def cache_slice(self, mc, cache, slot):
        """Gather one slot: pooled cache -> batch-1 cache (same treedef).
        ``slot`` may be a traced int32 — the op is jit-compatible."""
        axes = self.cache_slot_axes(mc)
        return {
            k: slot_slice_leaf(v, slot, axes.get(k, 0))
            for k, v in cache.items()
        }

    def cache_insert(self, mc, cache, slot, one):
        """Scatter a batch-1 cache (e.g. from a fresh prefill) into ``slot``
        of the pooled cache.  Shared leaves take the incoming value — it is
        identical for every request (same params, same max_len grid)."""
        axes = self.cache_slot_axes(mc)
        return {
            k: slot_insert_leaf(v, one[k], slot, axes.get(k, 0))
            for k, v in cache.items()
        }

    def cache_reset(self, mc, cache, slot):
        """Zero one slot (pure function) so an evicted request's state
        cannot leak into the slot's next occupant."""
        axes = self.cache_slot_axes(mc)
        return {
            k: slot_zero_leaf(v, slot, axes.get(k, 0))
            for k, v in cache.items()
        }

    # ------------------------------------------------------------ metadata
    def state_bytes(self, cfg, max_len: int) -> int:
        """Decode-state bytes per sequence (batch 1, bf16 cache) at
        ``max_len`` — must match ``init_cache`` exactly (conformance-tested)."""
        raise NotImplementedError

    def flops(self, cfg, L: int) -> float:
        """Forward FLOPs for one length-L sequence (×2 for mul+add)."""
        raise NotImplementedError


# ------------------------------------------------------ slot-contract leaf ops
#
# The single implementation of per-leaf slot slice / insert / zero, used by
# both the TokenMixer.cache_* methods (flat per-layer caches) and the
# lm-level pooled-tree variants (repro.models.lm.slot_insert et al., where
# scan-stacked group caches shift the slot axis by one).  ``axis < 0`` marks
# a leaf shared across slots: never sliced, inserted over wholesale, never
# reset.  ``slot`` may be a traced int32 — everything is jit-compatible.

def slot_slice_leaf(leaf, slot, axis: int):
    import jax

    if axis < 0:
        return leaf
    return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis)


def slot_insert_leaf(leaf, new, slot, axis: int):
    import jax

    if axis < 0:
        return new.astype(leaf.dtype)
    return jax.lax.dynamic_update_slice_in_dim(
        leaf, new.astype(leaf.dtype), slot, axis
    )


def slot_zero_leaf(leaf, slot, axis: int):
    import jax
    import jax.numpy as jnp

    if axis < 0:
        return leaf
    sl = jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis)
    return jax.lax.dynamic_update_slice_in_dim(
        leaf, jnp.zeros_like(sl), slot, axis
    )


_REGISTRY: Dict[str, TokenMixer] = {}
_builtins_loaded = False


def register_mixer(cls):
    """Class decorator: instantiate and register under ``cls.name``.

    Duplicate names raise (unless it is the same class re-imported): the
    registry is the extension point, and silently shadowing e.g. "hyena"
    would swap the mixer under every config with no warning.
    """
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a non-empty 'name'")
    prev = _REGISTRY.get(inst.name)
    if prev is not None and (
        type(prev).__module__ != cls.__module__
        or type(prev).__qualname__ != cls.__qualname__
    ):
        raise ValueError(
            f"mixer '{inst.name}' already registered by "
            f"{type(prev).__module__}.{type(prev).__qualname__}"
        )
    _REGISTRY[inst.name] = inst
    return cls


_builtins_loading = False


def _ensure_builtins() -> None:
    global _builtins_loaded, _builtins_loading
    if _builtins_loaded or _builtins_loading:
        return
    # reentrancy guard only while importing: a builtin module calling
    # get_mixer() mid-import must not recurse, but a *failed* import leaves
    # the loaded flag unset so the original ImportError resurfaces on the
    # next call instead of a misleading "unknown mixer".
    _builtins_loading = True
    try:
        for mod in _BUILTIN_MODULES:
            importlib.import_module(mod)
        _builtins_loaded = True
    finally:
        _builtins_loading = False


def get_mixer(name: str) -> TokenMixer:
    _ensure_builtins()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown mixer '{name}'; registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


def registered_mixers() -> Dict[str, TokenMixer]:
    _ensure_builtins()
    return dict(_REGISTRY)


def mixer_names() -> tuple:
    return tuple(sorted(registered_mixers()))
