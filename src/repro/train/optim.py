"""AdamW + schedules, built in-repo (optax is not available offline).

Matches the paper's training hyper-parameters (Table A.1/A.3): AdamW
β=(0.9, 0.98), weight decay 0.1, linear warmup + cosine decay, global-norm
gradient clipping.  Optimizer state is a plain pytree so it shards/
checkpoints exactly like parameters (FSDP shards m/v with the weights).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 6e-4
    b1: float = 0.9
    b2: float = 0.98
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # cosine | constant | linear


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    else:  # cosine
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * 0.5 * (
            1.0 + jnp.cos(math.pi * frac)
        )
    return cfg.lr * warm * decay


def init_adamw(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(
    cfg: AdamWConfig, grads, opt_state, params
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on tensors with >= 2 dims (skip norms/biases)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
