"""Training step factory: microbatch gradient accumulation, mixed precision
(``Policy.cast_compute`` at the top of every step), optional int8
error-feedback gradient compression on the cross-pod axis
(``TrainConfig.grad_compression="int8_ef"`` — residuals live in the train
state under ``"cgrad"`` so they checkpoint and reshard like the Adam
moments; DESIGN.md §10), jit with donated state.

The returned step is mesh-agnostic: under a mesh (``repro.distributed.ctx``)
the in/out shardings come from the rule engine via the shared
``ExecutionContext`` (``TrainConfig.apply_context(mesh=...)`` →
``ctx.train_state_shardings`` — the same substrate serving runs on,
DESIGN.md §9); on one device it's plain jit.  This is the same function the
multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.policy import BF16, Policy
from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard
from repro.distributed.execution import ExecutionContext
from repro.models import lm
from repro.train import optim as O


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: O.AdamWConfig = dataclasses.field(default_factory=O.AdamWConfig)
    microbatches: int = 1  # gradient accumulation steps
    remat: bool = True
    conv_backend: Optional[str] = None  # hyena long-conv backend override
    moe_aux_weight: float = 0.01
    z_loss_weight: float = 1e-4
    unroll: bool = False  # python-loop layer stack (dry-run cost probes)
    remat_policy: str = "nothing"  # nothing | dots | dots_no_batch
    # fp32 master params, policy-cast compute at the top of the jitted step
    policy: Policy = BF16
    fsdp: bool = True  # ZeRO-3 embed-family dims over data under a mesh
    # int8 error-feedback compression of the gradient all-reduce
    # (None | "int8_ef"); residuals ride in the train state as "cgrad"
    grad_compression: Optional[str] = None
    # context-parallel training: shard the batch's sequence dim (and the
    # residual stream) over this mesh axis; None = off.  See DESIGN.md §12.
    cp_axis: Optional[str] = None
    # reversible dual-stream substrate: O(1) activation memory over the
    # scanned depth via the coupling custom_vjp (DESIGN.md §15).  Parameter
    # and optimizer trees are identical either way, so checkpoints restore
    # across a flag flip bit-for-bit.  Training-only — serving ignores it.
    reversible: bool = False

    def __post_init__(self):
        if self.grad_compression not in (None, "int8_ef"):
            raise ValueError(
                f"grad_compression must be None or 'int8_ef', "
                f"got {self.grad_compression!r}"
            )

    def apply_context(self, mesh=None) -> ExecutionContext:
        """The single resolution point for execution options: constructing
        the context validates the conv backend / remat policy up front.
        Pass the mesh to get rule-driven state/cache shardings from the
        same object (``ctx.train_state_shardings`` et al.)."""
        return ExecutionContext(
            conv_backend=self.conv_backend,
            remat=self.remat,
            remat_policy=self.remat_policy,
            unroll=self.unroll,
            mesh=mesh,
            policy=self.policy,
            fsdp=self.fsdp,
            cp_axis=self.cp_axis,
            reversible=self.reversible,
        )


def init_train_state(key, cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    """Fresh train state: ``{"params", "opt"}`` plus — when ``tcfg`` enables
    gradient compression — the ``"cgrad"`` error-feedback residual tree
    (fp32 zeros mirroring the params, so it checkpoints/reshards with them).
    """
    from repro.common.param import split_params
    from repro.distributed import compression

    params, axes = split_params(lm.init_lm(key, cfg))
    state = {"params": params, "opt": O.init_adamw(params)}
    if tcfg is not None and tcfg.grad_compression:
        state["cgrad"] = compression.init_residuals(params)
    return state, axes


def abstract_train_state(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    """(ShapeDtypeStruct train-state tree, logical param-axes tree) without
    allocating — the one description of the train-state shape shared by the
    resumable loop's restore path and the dry-run's lowering (no caller
    hand-builds ``{"m", "v", "step"}`` mirrors)."""
    captured = {}

    def build():
        state, axes = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        captured["axes"] = axes
        return state

    struct = jax.eval_shape(build)
    return struct, captured["axes"]


def cp_shift_targets(tokens, mesh=None, cp_axis: Optional[str] = None,
                     ignore: int = lm.IGNORE):
    """Next-token LM targets: ``labels[t] = tokens[t+1]``, last *global*
    position = ``ignore``.

    Under context parallelism tokens arrive sequence-sharded, so position
    ``Lp-1`` of shard ``i`` needs position ``0`` of shard ``i+1`` — a
    one-token halo exchange (``ppermute``) instead of any resharding of
    the (B, L) tensor.  Without a cp mesh this is the plain shift.
    """
    if mesh is None or cp_axis is None or mesh.shape.get(cp_axis, 1) <= 1:
        return jnp.concatenate(
            [tokens[:, 1:], jnp.full_like(tokens[:, :1], ignore)], axis=1
        )
    from jax.sharding import PartitionSpec as P

    from repro.distributed.ctx import shard_map
    from repro.distributed.spconv import _batch_specs

    P_sz = mesh.shape[cp_axis]
    bspec, _ = _batch_specs(mesh, cp_axis, tokens.shape[0])

    def body(tb):
        idx = jax.lax.axis_index(cp_axis)
        # halo: every shard receives the NEXT shard's first token column
        # (the last shard receives shard 0's — masked to `ignore` below)
        halo = jax.lax.ppermute(
            tb[:, :1], cp_axis,
            [((i + 1) % P_sz, i) for i in range(P_sz)],
        )
        lab = jnp.concatenate([tb[:, 1:], halo], axis=1)
        Lp = tb.shape[1]
        last_global = (jnp.arange(Lp) == Lp - 1)[None, :] & (idx == P_sz - 1)
        return jnp.where(last_global, jnp.full_like(lab, ignore), lab)

    fn = shard_map(
        body, mesh=mesh, in_specs=(P(bspec, cp_axis),),
        out_specs=P(bspec, cp_axis), check=False,
    )
    return fn(tokens)


def _loss(params, cfg: ModelConfig, tcfg: TrainConfig, ctx: ExecutionContext,
          batch):
    # mixed precision: fp32 master params enter the model policy-cast (one
    # cast at the step top; grads flow back to fp32 through the astype vjp)
    params = ctx.cast_compute(params)
    labels = batch.get("labels")
    if labels is None:
        # batches without pre-shifted labels (long-context smoke/bench):
        # derive them in-step; crossing shard boundaries costs one token of
        # halo exchange under cp
        from repro.distributed.execution import _mesh_or_ambient

        labels = cp_shift_targets(
            batch["tokens"], _mesh_or_ambient(ctx.mesh), ctx.cp_axis
        )
    return lm.loss_fn(
        params, cfg, batch["tokens"], labels,
        batch.get("frontend_embeds"),
        ctx=ctx,
        moe_aux_weight=tcfg.moe_aux_weight,
        z_loss_weight=tcfg.z_loss_weight,
        compute_dtype=ctx.compute_dtype or jnp.bfloat16,
    )


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    """(state, batch) -> (state, metrics).  batch leaves: (B, ...) with B =
    global batch; microbatching splits B into `microbatches` chunks and
    accumulates grads in fp32 (overlappable reduce per chunk)."""

    ctx = tcfg.apply_context()  # validates backend names before tracing
    grad_fn = jax.value_and_grad(
        lambda p, batch: _loss(p, cfg, tcfg, ctx, batch), has_aux=True
    )
    compress = tcfg.grad_compression == "int8_ef"
    if compress:
        from repro.distributed import compression

    def constrain(v):
        # batch over data; under cp the sequence dim (dim 1) over cp_axis —
        # tokens/labels never materialize at full L per chip
        axes = ["data"] + [None] * (v.ndim - 1)
        if tcfg.cp_axis is not None and v.ndim >= 2:
            axes[1] = tcfg.cp_axis
        return shard(v, *axes)

    def step(state, batch):
        params = state["params"]
        batch = {k: v for k, v in batch.items() if v is not None}
        batch = {k: constrain(v) for k, v in batch.items()}
        n = tcfg.microbatches
        if n > 1:
            bad = {k: v.shape[0] for k, v in batch.items() if v.shape[0] % n}
            if bad:
                k, B = next(iter(bad.items()))
                raise ValueError(
                    f"make_train_step: microbatches={n} must divide the "
                    f"global batch size B={B} (leaf '{k}' has shape[0]={B} "
                    f"on the data axis {'/'.join(ctx.data_axes)}); use a "
                    f"batch size that is a multiple of {n} or set "
                    f"microbatches to a divisor of {B}."
                )
        if n == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def split(v):
                B = v.shape[0]
                return v.reshape((n, B // n) + v.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                m_acc = jax.tree_util.tree_map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            m0 = jax.eval_shape(lambda: grad_fn(
                params, jax.tree_util.tree_map(lambda v: v[0], micro))[0][1])
            m0 = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, msum), _ = jax.lax.scan(acc_step, (g0, m0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / n, msum)
        out = {}
        if compress:
            # int8 error-feedback on the reduced gradient, scaled by the
            # per-tensor global amax (what compressed_psum pmax-agrees
            # on).  One rounding of the reduced value — the tight end of
            # the wire channel, which rounds per-shard partials (see
            # distributed/compression.py).
            grads, out["cgrad"], cm = compression.apply(grads, state["cgrad"])
            metrics.update(cm)
        new_params, new_opt, om = O.adamw_update(
            tcfg.optimizer, grads, state["opt"], params
        )
        metrics.update(om)
        out.update({"params": new_params, "opt": new_opt})
        return out, metrics

    return step


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig, donate: bool = True):
    step = make_train_step(cfg, tcfg)
    return jax.jit(step, donate_argnums=(0,) if donate else ())
