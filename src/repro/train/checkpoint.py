"""Sharded, fault-tolerant checkpointing (orbax is not available offline).

Layout:  <dir>/step_<N>/
           manifest.json        tree structure, shapes/dtypes, sha256s, meta
           <leaf-key>.npy       one file per pytree leaf (host-gathered)
           _COMMITTED           sentinel written last (atomic rename commit)

Guarantees:
  * atomicity — a checkpoint without `_COMMITTED` is ignored (crash-safe);
    writes go to `tmp_step_<N>` then a single directory rename commits.
  * integrity — per-leaf sha256 verified on restore.
  * elasticity — restore takes target shardings for a *different* mesh
    shape and device_puts each leaf accordingly (elastic re-mesh restart);
    arbitrary pytrees (train state + data-loader cursor) round-trip.
  * async — `AsyncCheckpointer` snapshots to host memory synchronously
    (cheap) and writes on a worker thread, overlapping the next train steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np


def _to_savable(arr: np.ndarray):
    """numpy can't serialize bfloat16 — persist as a uint16 view with the
    logical dtype recorded in the manifest."""
    if arr.dtype == ml_dtypes.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_savable(arr: np.ndarray, logical_dtype: str):
    if logical_dtype == "bfloat16":
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "~".join(parts) or "root"


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def save(directory: str, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f"tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "meta": meta or {}, "leaves": []}
    for path, leaf in leaves:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        arr_save, logical_dtype = _to_savable(arr)
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr_save)
        manifest["leaves"].append(
            {
                "key": key,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": logical_dtype,
                "sha256": _sha(arr_save),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    open(os.path.join(tmp, "_COMMITTED"), "w").close()
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "_COMMITTED")):
            s = int(m.group(1))
            best = s if best is None or s > best else best
    return best


def restore(
    directory: str,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    verify: bool = True,
) -> Any:
    """Restore into the structure of `like`; `shardings` (same structure or
    None) re-shards every leaf — pass shardings built for the *current*
    mesh to restart elastically on a different topology."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    paths_like = jax.tree_util.tree_flatten_with_path(like)
    leaves_like, treedef = paths_like
    flat_shardings = (
        _flatten_shardings(shardings, leaves_like)
        if shardings is not None
        else [None] * len(leaves_like)
    )
    out = []
    for (path_k, leaf), shd in zip(leaves_like, flat_shardings):
        key = _leaf_key(path_k)
        ent = by_key.get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(path, ent["file"]))
        if verify and _sha(arr) != ent["sha256"]:
            raise IOError(f"checksum mismatch for {key}")
        arr = _from_savable(arr, ent["dtype"])
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    )
    return tree, manifest["meta"], step


def _flatten_shardings(shardings, leaves_like):
    """Flatten a shardings tree leaf-aligned with the restore target.

    The structure must mirror the target exactly (a leaf per target leaf,
    ``None`` = default placement).  A structure that merely *flattens* to
    the same length would silently pair leaves with the wrong shardings —
    an elastic re-mesh restart would place tensors by someone else's rule —
    so any mismatch raises with the first offending key.
    """
    is_leaf = lambda x: x is None or hasattr(x, "spec")
    flat_s, _ = jax.tree_util.tree_flatten_with_path(shardings, is_leaf=is_leaf)
    keys_like = [_leaf_key(p) for p, _ in leaves_like]
    keys_s = [_leaf_key(p) for p, _ in flat_s]
    if keys_s != keys_like:
        missing = [k for k in keys_like if k not in keys_s]
        extra = [k for k in keys_s if k not in keys_like]
        offender = (missing + extra or ["<leaf order>"])[0]
        raise ValueError(
            f"shardings tree does not match restore target at {offender!r} "
            f"({len(flat_s)} sharding leaves vs {len(keys_like)} target "
            f"leaves; missing={missing[:3]}, unexpected={extra[:3]})"
        )
    return [s for _, s in flat_s]


def cleanup(directory: str, keep_last: int = 3) -> None:
    """Delete all but the newest ``keep_last`` *committed* checkpoints.

    Retention is explicit: ``keep_last`` must be >= 1 (there is no
    "delete everything" spelling — a preempted run's only restart point is
    the newest committed step).  Uncommitted ``step_*`` debris from crashed
    writes is always removed; the in-flight ``tmp_step_*`` staging dirs are
    never touched (the writer owns them).
    """
    if keep_last < 1:
        raise ValueError(
            f"cleanup(keep_last={keep_last}): retention must keep at least "
            "the newest committed checkpoint"
        )
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1))
        for name in os.listdir(directory)
        for m in [re.fullmatch(r"step_(\d+)", name)]
        if m
    )
    committed = [
        s for s in steps
        if os.path.exists(os.path.join(directory, f"step_{s:08d}", "_COMMITTED"))
    ]
    keep = set(committed[-keep_last:])
    for s in steps:
        if s not in keep:
            shutil.rmtree(
                os.path.join(directory, f"step_{s:08d}"), ignore_errors=True
            )


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread. `wait()` drains pending writes."""

    def __init__(self, directory: str, keep_last: int = 3):
        if keep_last < 1:
            raise ValueError("AsyncCheckpointer: keep_last must be >= 1")
        self.directory = directory
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, meta = item
            try:
                save(self.directory, step, host_tree, meta)
                cleanup(self.directory, self.keep_last)
            except BaseException as e:  # surfaced on next save()/wait()
                with self._err_lock:
                    self._err = e
            finally:
                self._q.task_done()

    def _take_err(self) -> Optional[BaseException]:
        """Pop the latched background error (one raise per failure — a
        failed write must not poison every later save forever).  Locked
        against the worker's store so a failure landing mid-pop is never
        silently overwritten with None."""
        with self._err_lock:
            err, self._err = self._err, None
        return err

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None):
        err = self._take_err()
        if err:
            raise err
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )
        self._q.put((step, host_tree, meta))

    def wait(self):
        self._q.join()
        err = self._take_err()
        if err:
            raise err

    def close(self):
        """Drain, then shut the worker down.  The sentinel is enqueued even
        when a pending write failed (``wait`` re-raising must not leak the
        worker thread)."""
        try:
            self.wait()
        finally:
            self._q.put(None)
            self._thread.join(timeout=10)
