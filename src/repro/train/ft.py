"""Fault-tolerance utilities for long multi-pod runs.

The primitives (``PreemptionHandler``, ``StragglerMonitor``, ``retry``,
``Heartbeat``) are shared with the serve engines' failure-domain layer
(DESIGN.md §13) and live in :mod:`repro.common.ft`; this module re-exports
them so existing training callers keep importing ``repro.train.ft``.
"""
from __future__ import annotations

from repro.common.ft import (  # noqa: F401
    Heartbeat,
    PreemptionHandler,
    StragglerMonitor,
    retry,
)

__all__ = ["Heartbeat", "PreemptionHandler", "StragglerMonitor", "retry"]
