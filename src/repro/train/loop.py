"""The one preemption-safe, resumable training loop (DESIGN.md §10).

Every training entry point — both examples, the kill-and-resume tests, and
(through :func:`repro.train.trainer.abstract_train_state`) the multi-pod
dry-run — drives :class:`TrainLoop` instead of hand-rolling its own
step/checkpoint/telemetry lifecycle.  The loop owns, on the shared
``ExecutionContext`` substrate (§9):

  * **Resume-from-latest-committed.**  One checkpoint tree
    ``{"train": state, "rng": base_key}`` + manifest meta
    ``{"step", "loader"}`` captures everything a bit-exact restart needs:
    train state (params / Adam moments / compression residuals), the
    data-loader cursor, the loop's base PRNG key, and the step count.
    Restore places every leaf through ``ctx.train_state_shardings`` — an
    elastic re-mesh restart lands sharded by rule, not by replaying the
    original topology.
  * **Async checkpointing** overlapped with compute
    (:class:`repro.train.checkpoint.AsyncCheckpointer`), with explicit
    retention (``keep_last``) and bounded-backoff retry on restore I/O.
  * **Preemption draining** — SIGTERM sets a flag; the loop finishes the
    in-flight step, writes a final committed checkpoint at the step
    boundary, and returns ``status="preempted"``.
  * **Telemetry** — straggler EWMA, heartbeat liveness file, per-step
    ``on_step`` hook, periodic logging.

Data sources are either a *stateless* callable ``(step, rng) -> batch``
(synthetic tasks: resume needs only the step and the checkpointed base
key) or a *stateful* stream exposing ``next_batch()/state()/restore()``
(:class:`repro.data.lm_data.TokenStream`); the loop wraps streams in a
:class:`~repro.data.lm_data.Prefetcher` *after* restoring the cursor and
checkpoints the consumed-batch cursor, never the prefetch head.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import lm_data
from repro.train import checkpoint as ckpt
from repro.common import ft
from repro.train.trainer import (
    TrainConfig,
    abstract_train_state,
    init_train_state,
    jit_train_step,
)


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None  # None = no checkpointing/heartbeat
    ckpt_every: int = 100
    keep_last: int = 3
    heartbeat_interval: Optional[float] = 30.0  # None = no heartbeat file
    log_every: int = 20
    prefetch_depth: int = 2
    donate: bool = True
    straggler_threshold: float = 2.0
    restore_attempts: int = 3  # bounded-backoff retry on restore I/O

    def __post_init__(self):
        if self.total_steps < 1:
            raise ValueError("total_steps must be >= 1")
        if self.ckpt_every < 1:
            raise ValueError("ckpt_every must be >= 1")
        if self.keep_last < 1:
            raise ValueError("keep_last must be >= 1 (explicit retention)")


@dataclasses.dataclass
class LoopResult:
    status: str  # "done" | "preempted"
    state: Any
    step: int  # completed steps
    history: List[float]  # per-step loss, this run only
    metrics: Dict[str, float]  # last step's metrics (host floats)
    stragglers: int


class TrainLoop:
    """Owns the step/checkpoint/telemetry lifecycle for one training run."""

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        lcfg: LoopConfig,
        *,
        mesh=None,
        handler: Optional[ft.PreemptionHandler] = None,
        log: Callable[[str], None] = print,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.lcfg = lcfg
        self.ectx = tcfg.apply_context(mesh=mesh)
        self.log = log
        # injectable for tests (signals=()); created lazily otherwise so
        # constructing a loop off the main thread stays legal
        self._handler = handler
        self.monitor = ft.StragglerMonitor(threshold=lcfg.straggler_threshold)
        self._struct, self._axes = abstract_train_state(cfg, tcfg)
        self._step_fn = jit_train_step(cfg, tcfg, donate=lcfg.donate)

    # ------------------------------------------------------------- restore
    def _ckpt_shardings(self):
        if self.ectx.mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec as P

        return {
            "train": self.ectx.train_state_shardings(self._axes, self._struct),
            "rng": NamedSharding(self.ectx.mesh, P()),
        }

    def restore_or_init(self, key: jax.Array):
        """(state, base_key, start_step, loader_state) — from the latest
        committed checkpoint when one exists, else a fresh init from
        ``key``.  Leaves land placed by the context's rules either way."""
        shardings = self._ckpt_shardings()
        d = self.lcfg.ckpt_dir
        if d and ckpt.latest_step(d) is not None:
            like = {
                "train": self._struct,
                "rng": jax.eval_shape(lambda: jax.random.PRNGKey(0)),
            }
            tree, meta, step = ft.retry(
                lambda: ckpt.restore(d, like, shardings=shardings),
                attempts=self.lcfg.restore_attempts,
            )
            return tree["train"], tree["rng"], step, meta.get("loader")
        state, _ = init_train_state(key, self.cfg, self.tcfg)
        if shardings is not None:
            state = self.ectx.place(state, shardings["train"])
        return state, key, 0, None

    # ---------------------------------------------------------------- data
    def _wrap_data(self, data, loader_state, resumed: bool):
        """Returns (fetch(step, rng) -> batch, loader_meta() -> state|None,
        close()).  Source kind and checkpointed loader state must agree in
        BOTH directions — a mid-trajectory source swap would silently fork
        the run from its uninterrupted twin."""
        if callable(data):
            if loader_state is not None and "cursor" in loader_state:
                # a stream checkpoint can't drive a stateless source
                raise ValueError(
                    "checkpoint carries a loader cursor but the data source "
                    "is a stateless callable"
                )
            return (lambda step, rng: data(step, rng)), (lambda: None), (lambda: None)
        if loader_state is not None:
            data.restore(loader_state)
        elif resumed:
            # the opposite swap: a checkpoint written with a stateless
            # source cannot position a stream — it would restart at
            # cursor 0 mid-trajectory
            raise ValueError(
                "checkpoint has no loader cursor but the data source is a "
                "stream — resuming would replay batches from cursor 0"
            )
        pf = lm_data.Prefetcher(data, depth=self.lcfg.prefetch_depth)
        return (
            (lambda step, rng: pf.next()),
            (lambda: getattr(pf, "consumed_state", None)),
            pf.close,
        )

    def _place_batch(self, batch):
        out = {}
        for k, v in batch.items():
            if v is None:
                continue
            v = jnp.asarray(v)
            if self.ectx.mesh is not None:
                seq = v.shape[1] if v.ndim >= 2 else None
                v = jax.device_put(
                    v, self.ectx.data_sharding(v.ndim, v.shape[0], seq)
                )
            out[k] = v
        return out

    # ----------------------------------------------------------------- run
    def run(
        self,
        data,
        *,
        key: Optional[jax.Array] = None,
        on_step: Optional[Callable[[int, Dict[str, float], float], None]] = None,
    ) -> LoopResult:
        """Train to ``total_steps`` (or a preemption boundary).

        ``data``: stateless ``(step, rng) -> batch`` callable or a stateful
        stream (see module docstring).  ``key`` seeds a fresh run; once a
        checkpoint exists the checkpointed base key wins, so restarts never
        fork the trajectory.  ``on_step(step, metrics, seconds)`` fires
        after every step (telemetry hook; step counts completed steps).
        """
        lcfg = self.lcfg
        if key is None:
            key = jax.random.PRNGKey(0)
        state, base_key, start, loader_state = self.restore_or_init(key)
        if start >= lcfg.total_steps:
            self.log(f"nothing to do: checkpoint at step {start}")
            return LoopResult("done", state, start, [], {}, 0)
        if start:
            self.log(f"resumed from step {start} (latest committed)")
        fetch, loader_meta, close_data = self._wrap_data(
            data, loader_state, resumed=start > 0
        )

        handler = self._handler or ft.PreemptionHandler()
        writer = heartbeat = None
        if lcfg.ckpt_dir:
            os.makedirs(lcfg.ckpt_dir, exist_ok=True)
            writer = ckpt.AsyncCheckpointer(lcfg.ckpt_dir, lcfg.keep_last)
            if lcfg.heartbeat_interval:
                heartbeat = ft.Heartbeat(
                    os.path.join(lcfg.ckpt_dir, "heartbeat"),
                    lcfg.heartbeat_interval,
                )
                heartbeat.start()

        def save(step: int):
            if writer is not None:
                writer.save(
                    step,
                    {"train": state, "rng": base_key},
                    meta={"step": step, "loader": loader_meta()},
                )

        # per-step losses stay device-side between boundaries so the host
        # never blocks on step i before dispatching step i+1; they flush
        # to host floats (one batched transfer) at every log/checkpoint/
        # preempt boundary, keeping at most ~ckpt_every scalars alive
        history: List[float] = []
        pending: List[Any] = []
        metrics: Dict[str, Any] = {}
        to_host = lambda m: {k: float(v) for k, v in m.items()}

        def flush_history():
            if pending:
                history.extend(
                    float(x) for x in jax.device_get(list(pending))
                )
                pending.clear()

        status = "done"
        last_saved = -1
        try:
            with self.ectx.scope():
                for i in range(start, lcfg.total_steps):
                    t0 = time.time()
                    batch = self._place_batch(
                        fetch(i, jax.random.fold_in(base_key, i))
                    )
                    state, metrics = self._step_fn(state, batch)
                    pending.append(metrics["loss"])
                    dt = time.time() - t0
                    slow = self.monitor.record(i, dt)
                    done = i + 1
                    if on_step is not None:
                        on_step(done, to_host(metrics), dt)
                    if done % lcfg.ckpt_every == 0 and done < lcfg.total_steps:
                        flush_history()
                        save(done)
                        last_saved = done
                    if handler.preempted():
                        # drain: final committed checkpoint at this step
                        # boundary, then a clean exit the controller can
                        # restart from
                        if last_saved != done:
                            save(done)
                            last_saved = done
                        status = "preempted"
                        self.log(
                            f"preempted — committed step {done}, exiting"
                        )
                        flush_history()
                        return LoopResult(
                            status, state, done, history,
                            to_host(metrics), self.monitor.stragglers,
                        )
                    if done % lcfg.log_every == 0 or done == lcfg.total_steps:
                        flush_history()
                        tok = batch["tokens"].size if "tokens" in batch else 0
                        self.log(
                            f"step {done:5d} loss {history[-1]:.3f} "
                            f"gnorm {float(metrics.get('grad_norm', 0.0)):.2f} "
                            f"{tok / dt:.0f} tok/s"
                            + (" [straggler]" if slow else "")
                        )
            if last_saved != lcfg.total_steps:
                save(lcfg.total_steps)
            flush_history()
            return LoopResult(
                status, state, lcfg.total_steps, history,
                to_host(metrics) if metrics else {},
                self.monitor.stragglers,
            )
        finally:
            if writer is not None:
                writer.close()  # drains pending writes (and re-raises)
            if heartbeat is not None:
                heartbeat.stop()
            close_data()
            if self._handler is None:
                handler.restore()
