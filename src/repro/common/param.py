"""Parameter trees with logical-axis annotations.

Every ``init_*`` function in this codebase returns a pytree whose leaves are
``Ax(value, axes)`` — an array paired with a tuple of *logical* axis names
("embed", "heads", "experts", ...).  ``split_params`` separates the tree into
a plain value tree (fed to jit) and a parallel axes tree (fed to the sharding
rule engine in ``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class Ax:
    """An array annotated with logical axis names (one per dim)."""

    value: Any
    axes: Tuple[Optional[str], ...]

    def __post_init__(self):
        if self.axes is not None and np.ndim(self.value) != len(self.axes):
            raise ValueError(
                f"Ax: value ndim {np.ndim(self.value)} != axes {self.axes}"
            )


def _ax_flatten(a: "Ax"):
    return (a.value,), a.axes


def _ax_unflatten(axes, children):
    obj = object.__new__(Ax)
    obj.value = children[0]
    obj.axes = axes
    return obj


# Registered as a pytree node so vmap-ed inits can stack layers; the ndim
# check is skipped on unflatten (stacked values gain leading dims — the
# sharding rule engine treats extra leading dims as replicated).
jax.tree_util.register_pytree_node(Ax, _ax_flatten, _ax_unflatten)


def _is_ax(x) -> bool:
    return isinstance(x, Ax)


def split_params(tree):
    """(values, logical_axes) from an Ax-annotated tree."""
    values = jax.tree_util.tree_map(lambda a: a.value, tree, is_leaf=_is_ax)
    axes = jax.tree_util.tree_map(lambda a: a.axes, tree, is_leaf=_is_ax)
    return values, axes


def merge_params(values, axes):
    return jax.tree_util.tree_map(Ax, values, axes)


def param_count(values) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(values)))


def param_bytes(values) -> int:
    return int(
        sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(values))
    )
