"""Weight initializers (fp32 at init; compute dtype is a policy concern)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal(key, shape, stddev=0.02, dtype=jnp.float32):
    return stddev * jax.random.normal(key, shape, dtype)


def lecun(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return jax.random.normal(key, shape, dtype) / np.sqrt(fan_in)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)
