"""Mixed-precision policy: fp32 master params, bf16 compute, fp32 reductions.

``cast_compute`` is applied to the parameter tree at the top of each jitted
train step and once at serve-engine construction, via the shared
``ExecutionContext`` (``repro.distributed.execution``; DESIGN.md §9) —
gradients flow back to the fp32 masters through the astype vjp.  Norms /
softmax / FFT run in fp32 internally regardless (handled at the op level).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32

    def cast_compute(self, tree):
        def cast(x):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(self.compute_dtype)
            return x

        return jax.tree_util.tree_map(cast, tree)


FP32 = Policy(compute_dtype=jnp.float32)
BF16 = Policy()


def get_policy(name: str) -> Policy:
    return {"fp32": FP32, "bf16": BF16}[name]
