"""Fault-tolerance primitives shared by training AND serving.

Grew up in ``repro.train.ft`` for long multi-pod runs; the serve engines'
failure-domain layer (DESIGN.md §13) reuses the same primitives, so they
live here and ``repro.train.ft`` re-exports them.

* ``PreemptionHandler`` — SIGTERM/SIGINT sets a flag; the train loop
  checkpoints and exits cleanly at the next step boundary (TPU preemption
  notice pattern).
* ``StragglerMonitor`` — EWMA of step wall-time; flags steps slower than
  ``threshold×`` the moving average (on real pods this feeds the controller
  that swaps a slow host; the serve engines surface it via ``health()``).
* ``retry`` — bounded exponential-backoff retry for transient failures
  (checkpoint I/O, coordination-service hiccups, transient serve-step
  errors).
* ``Heartbeat`` — periodic liveness file; a controller can detect a hung
  host by mtime.  ``beat()`` writes atomically (tmp + ``os.replace``) so a
  monitor polling the file can never read a torn or empty beat.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from typing import Callable, Optional


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._on_signal)
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self._flag.set()

    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self):  # for tests / manual drain
        self._flag.set()

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.stragglers = 0
        self.last_report: Optional[str] = None

    def record(self, step: int, seconds: float) -> bool:
        slow = False
        if self.ewma is not None and seconds > self.threshold * self.ewma:
            self.stragglers += 1
            self.last_report = (
                f"step {step}: {seconds:.3f}s vs EWMA {self.ewma:.3f}s "
                f"(x{seconds / self.ewma:.1f}) — straggler"
            )
            slow = True
        self.ewma = (
            seconds
            if self.ewma is None
            else (1 - self.alpha) * self.ewma + self.alpha * seconds
        )
        return slow


def retry(fn: Callable, *, attempts: int = 3, base_delay: float = 0.1,
          exceptions=(IOError, OSError)):
    """Call fn() with bounded exponential backoff.  ``attempts`` must be
    >= 1 — silently returning ``None`` without ever calling ``fn`` would
    turn a mis-typed retry budget into a skipped checkpoint write."""
    if attempts < 1:
        raise ValueError(f"retry: attempts must be >= 1, got {attempts}")
    for i in range(attempts):
        try:
            return fn()
        except exceptions:
            if i == attempts - 1:
                raise
            if base_delay > 0:
                time.sleep(base_delay * (2 ** i))


class Heartbeat:
    """Periodic liveness file.  ``start``/``stop`` form a restartable pair:
    each ``start`` spins up a fresh thread+event, and ``stop`` joins the
    thread (the event wakes the ``wait`` immediately) so callers know no
    further beat can race a directory teardown.

    ``beat()`` is atomic: the timestamp lands in a sibling tmp file first
    and ``os.replace`` swaps it in, so a monitor that polls the path reads
    either the previous beat or the new one — never a torn/empty file
    (and a crash mid-beat leaves the previous beat intact)."""

    def __init__(self, path: str, interval: float = 30.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self, stop: threading.Event):
        while not stop.wait(self.interval):
            self.beat()

    def beat(self):
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(str(time.time()))
        os.replace(tmp, self.path)

    def start(self):
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("Heartbeat already running")
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(self._stop,), daemon=True
        )
        self.beat()
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
