"""Data-controlled matrix form of Hyena (paper §3.2, App. D.1).

``y = H(u) v`` with ``H(u) = D_x^N S_h^N ⋯ D_x^1 S_h^1`` where ``D_x^n =
diag(x^n)`` and ``S_h^n`` is the lower-triangular (causal) Toeplitz matrix of
filter ``h^n``.  These utilities materialize the factors for testing
(recurrence == matrix form), interpretability plots (App. D.1 figures), and
the H3/GSS special-case checks (Rmk 3.2).  Never used in the fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import filters as F
from repro.core.operator import HyenaConfig, _project


def toeplitz(h: jax.Array) -> jax.Array:
    """Causal (lower-triangular) Toeplitz matrix S_h from a length-L filter.

    h: (..., L) -> (..., L, L) with S[i, j] = h[i-j] for i >= j else 0.
    """
    L = h.shape[-1]
    t = jnp.arange(L)
    idx = t[:, None] - t[None, :]
    mask = idx >= 0
    S = jnp.where(mask, h[..., jnp.clip(idx, 0, L - 1)], 0.0)
    return S


def materialize_H(params, cfg: HyenaConfig, u: jax.Array) -> jax.Array:
    """H(u): (B, D, L, L) — one data-controlled matrix per channel (the paper
    notes Hyena has a different matrix per channel since it does not use
    heads; App. D.1).  Includes the per-order skip term: the effective
    per-order operator is ``D_x^n (S_h^n + skip_n I)``.
    """
    B, L, D = u.shape
    _, xs = _project(params, cfg, u)
    h = F.evaluate_filters(params["filters"], cfg.filter, L)  # (N, D, L)
    skip = F.filter_skip(params["filters"], cfg.filter)  # (N, D)
    eye = jnp.eye(L, dtype=jnp.float32)
    H = jnp.broadcast_to(eye, (B, D, L, L))
    for n in range(cfg.order):
        S = toeplitz(h[n].astype(jnp.float32))  # (D, L, L)
        S = S + skip[n][:, None, None] * eye  # (D, L, L)
        x = xs[n].astype(jnp.float32).transpose(0, 2, 1)  # (B, D, L)
        # D_x^n (S^n @ H)
        H = jnp.einsum("bdl,dlm,bdmk->bdlk", x, S, H)
    return H


def apply_H(params, cfg: HyenaConfig, u: jax.Array) -> jax.Array:
    """y via the materialized matrix (O(L²) — tests only)."""
    B, L, D = u.shape
    v, _ = _project(params, cfg, u)
    H = materialize_H(params, cfg, u)
    y = jnp.einsum("bdlk,bkd->bld", H, v.astype(jnp.float32)).astype(u.dtype)
    y = y @ params["out_proj"]["w"].astype(u.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(u.dtype)
    return y
