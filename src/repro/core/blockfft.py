"""Block-FFT causal conv: the four-step (Bailey) FFT with the small DFTs
evaluated as dense matmuls — every FLOP lands on the MXU instead of the
VPU-bound radix-2 butterfly network.  This is the TPU analogue of
FlashConv's "block FFT" for tensor cores (H3 paper) and our main
beyond-paper optimization for Hyena's long convs at training lengths
(EXPERIMENTS.md §Perf).

Four-step decomposition, N = R·S (x row-major A[r,s] = x[rS+s]):

    X[k1 + k2·R] = Σ_s W_S^{s k2} [ W_N^{s k1} Σ_r A[r,s] W_R^{r k1} ]

  1. DFT_R over rows      — (R×R) matmul, shared by all (batch, channel)
  2. twiddle W_N^{s·k1}   — elementwise
  3. DFT_S over columns   — (S×S) matmul

FLOP count 8·N·(R+S) real MACs vs 5·N·log₂N for radix-2 — ~2–4× more
arithmetic, but at MXU throughput (197 TF/s) instead of VPU (~4 TF/s), a
large net wall-clock win; the §Perf log quantifies it per shape.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def factor_candidates(N: int, limit: int = 6) -> Tuple[Tuple[int, int], ...]:
    """Valid (R, S) splits of N for the four-step transform, nearest-√N
    first — the autotune search space for this backend (core.autotune).

    Every divisor pair computes the same DFT; they differ only in how the
    work lands on the two small dense matmuls (R×R and S×S), so searching
    over them is semantics-preserving by construction."""
    divs = [r for r in range(1, math.isqrt(N) + 1) if N % r == 0]
    pairs = []
    for r in reversed(divs):  # closest to √N first
        pairs.append((r, N // r))
        if (N // r, r) != (r, N // r):
            pairs.append((N // r, r))
    return tuple(pairs[:limit])


def _factor(N: int) -> Tuple[int, int]:
    """N = R·S with R preferring the MXU-friendly power-of-two near √N.

    The four-step identity holds for ANY factorization, so when N's
    power-of-two part is small (odd / prime conv lengths pad to N = 2L
    with L odd) we fall back to the largest divisor ≤ √N instead of
    doubling R past N forever (the pre-fix behavior hung on prime L —
    caught by tests/test_conv_backends_prop.py).
    """
    R = 1 << max(math.ceil(math.log2(math.sqrt(N))), 0)
    while R <= N and N % R:
        R *= 2
    if R <= N and N % R == 0:
        return R, N // R
    R = max(r for r in range(1, math.isqrt(N) + 1) if N % r == 0)
    return R, N // R


@functools.lru_cache(maxsize=32)
def _dft_mats(N: int, factors: Optional[Tuple[int, int]] = None):
    # numpy on purpose: this cache is shared across jit traces, and jnp
    # constant construction inside a trace would poison it with tracers
    # from a long-dead trace (UnexpectedTracerError on the next jit).
    import numpy as np

    R, S = _factor(N) if factors is None else factors
    if R * S != N:
        raise ValueError(f"factors {factors} do not multiply to N={N}")
    r = np.arange(R)
    s = np.arange(S)
    FR = np.exp(-2j * np.pi * np.outer(r, r) / R).astype(np.complex64)
    FS = np.exp(-2j * np.pi * np.outer(s, s) / S).astype(np.complex64)
    TW = np.exp(
        -2j * np.pi * np.outer(r, s) / N
    ).astype(np.complex64)  # W_N^{k1·s}
    return R, S, FR, FS, TW


def _four_step_fft(x: jax.Array, N: int, factors=None) -> jax.Array:
    """x: (B, N, D) real/complex -> spectrum C (B, R, S, D) with
    X[k1 + k2·R] = C[:, k1, k2, :]."""
    R, S, FR, FS, TW = _dft_mats(N, factors)
    B, _, D = x.shape
    A = x.reshape(B, R, S, D).astype(jnp.complex64)
    Bm = jnp.einsum("kr,brsd->bksd", FR, A)
    Bm = Bm * TW[None, :, :, None]
    return jnp.einsum("bksd,sj->bkjd", Bm, FS)


def _four_step_ifft(C: jax.Array, N: int, factors=None) -> jax.Array:
    """Inverse of _four_step_fft (same layout). Returns (B, N, D) complex."""
    R, S, FR, FS, TW = _dft_mats(N, factors)
    Dm = jnp.einsum("bkjd,sj->bksd", C, jnp.conj(FS))
    Dm = Dm * jnp.conj(TW)[None, :, :, None]
    A = jnp.einsum("kr,bksd->brsd", jnp.conj(FR), Dm) / N
    B = C.shape[0]
    return A.reshape(B, N, C.shape[-1])


def blockfft_causal_conv(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L)
    skip: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,  # (B, L, D)
    *,
    factors: Optional[Tuple[int, int]] = None,  # autotuned (R, S) split
) -> jax.Array:
    from repro.core.fftconv import next_fast_len

    B, L, D = u.shape
    # any N >= 2L-1 keeps the first L outputs wrap-free; a 5-smooth N also
    # keeps the four-step factor split balanced for odd / prime-ish L
    N = next_fast_len(2 * L - 1)
    if factors is not None and factors[0] * factors[1] != N:
        factors = None  # stale plan for a different padded length
    u32 = u.astype(jnp.float32)
    up = jnp.pad(u32, ((0, 0), (0, N - L), (0, 0)))
    hp = jnp.pad(h.astype(jnp.float32).T, ((0, N - L), (0, 0)))[None]  # (1, N, D)
    U = _four_step_fft(up, N, factors)
    H = _four_step_fft(hp, N, factors)
    Y = U * H
    y = _four_step_ifft(Y, N, factors).real[:, :L, :]
    if skip is not None:
        y = y + u32 * skip[None, None, :].astype(jnp.float32)
    # downcast BEFORE the gate: fused == gate * unfused bit-for-bit
    # (see fftconv._fused_epilogue)
    y = y.astype(u.dtype)
    if gate is not None:
        y = y * gate.astype(u.dtype)
    return y
