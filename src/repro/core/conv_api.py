"""ConvBackend registry: the single dispatch point for Hyena's long
causal convolution (see DESIGN.md §2–3, §7).

Every backend implements the same contract —
``fn(u, h, skip, gate=None) -> y`` with ``u: (B, L, D)``, ``h: (D, L)``,
``skip: (D,) | None``, ``gate: (B, L, D) | None`` — plus capability
metadata used for *early* validation (at config/context construction, not
mid-forward) and for tooling (benchmarks iterate the registry instead of
hard-coding imports).

``gate`` is the Hyena recurrence's data-controlled multiplier
``xⁿ ⊙ conv(v)``: backends with ``supports_gate`` fuse it into the conv
itself (at the Pallas kernel's finalize, or in the single post-iFFT
elementwise pass), eliminating one full-tensor HBM write+read per order.
Fusion is bit-identical to the two-pass schedule ``gate * fn(u, h, skip)``
— a pure memory-traffic optimization that can never change model outputs
(DESIGN.md §7).  Backends without the flag still honor the argument — the
registry applies the gate as a separate multiply — so callers can use the
gated entry point unconditionally.

Adding a backend is one module + one ``register_conv_backend`` call; no
dispatch site anywhere else changes.  Backend resolution — including the
``REPRO_CONV_BACKEND`` environment override used by the launch layer — goes
through :func:`resolve_conv_backend`, the only place that env var is read.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional

ENV_VAR = "REPRO_CONV_BACKEND"
DEFAULT_BACKEND = "fft"


@dataclasses.dataclass(frozen=True)
class ConvBackend:
    """A registered long-conv implementation with capability flags.

    ``fn(u, h, skip, gate=None)``: depthwise causal conv of ``u (B, L, D)``
    with per-channel length-L filters ``h (D, L)``, optional residual gain
    ``skip (D,)``, and (when ``supports_gate``) a fused elementwise output
    gate ``gate (B, L, D)``.
    """

    name: str
    fn: Callable
    description: str = ""
    tag: str = ""  # short stable identifier for benchmark/report rows
    requires_pallas: bool = False  # Pallas lowering (interpret-mode off-TPU)
    mesh_aware: bool = False  # runs collective-free under a sharded mesh
    oracle: bool = False  # O(L²) reference — tests/tiny L only
    max_len: int = 0  # 0 = unconstrained; else largest supported L
    supports_gate: bool = False  # fn fuses the elementwise output gate

    def validate_len(self, L: int) -> None:
        if self.max_len and L > self.max_len:
            raise ValueError(
                f"conv backend '{self.name}' supports L <= {self.max_len}, "
                f"got {L}"
            )

    def __call__(self, u, h, skip=None, gate=None):
        if gate is None:
            return self.fn(u, h, skip)
        if self.supports_gate:
            return self.fn(u, h, skip, gate)
        # unfused fallback: same semantics, one extra full-tensor pass —
        # external registrations work before they learn the gate protocol
        return (gate * self.fn(u, h, skip).astype(gate.dtype)).astype(u.dtype)


_BACKENDS: Dict[str, ConvBackend] = {}


def register_conv_backend(backend: ConvBackend) -> ConvBackend:
    """Duplicate names raise unless the registration is identical — silent
    shadowing of e.g. 'fft' would swap the conv under every model."""
    prev = _BACKENDS.get(backend.name)
    if prev is not None and prev != backend:
        raise ValueError(f"conv backend '{backend.name}' already registered")
    _BACKENDS[backend.name] = backend
    return backend


def conv_backend_names() -> tuple:
    return tuple(sorted(_BACKENDS))


def registered_conv_backends() -> Dict[str, ConvBackend]:
    return dict(_BACKENDS)


def get_conv_backend(name: Optional[str]) -> ConvBackend:
    """Look up a backend; ``None`` means the registry default — the
    None-means-default rule lives here, not at dispatch sites."""
    name = name or DEFAULT_BACKEND
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown conv backend '{name}'; registered: "
            f"{list(conv_backend_names())}"
        )
    return _BACKENDS[name]


def resolve_conv_backend(
    override: Optional[str] = None, *, default: str = DEFAULT_BACKEND
) -> str:
    """One resolution point for the long-conv backend name.

    Priority: explicit ``override`` > ``$REPRO_CONV_BACKEND`` > ``default``.
    The resolved name is validated against the registry — unknown names
    raise immediately (config/launch time), naming the source of the bad
    name (a typo'd env var should not read like a code bug) and the sorted
    registered list.
    """
    env = os.environ.get(ENV_VAR)
    if override:
        name, source = override, "override"
    elif env:
        name, source = env, f"${ENV_VAR}"
    else:
        name, source = default, "default"
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown conv backend '{name}' (from {source}); registered "
            f"backends: {sorted(_BACKENDS)}"
        )
    return name


# --------------------------------------------------------------- built-ins
#
# The wrappers import lazily so that e.g. the Pallas toolchain is only
# touched when the 'toeplitz' backend is actually selected.

def _fft(u, h, skip=None, gate=None):
    from repro.core.fftconv import fft_causal_conv_sharded

    return fft_causal_conv_sharded(u, h, skip, gate)


def _fft_local(u, h, skip=None, gate=None):
    from repro.core.fftconv import fft_causal_conv

    return fft_causal_conv(u, h, skip, gate)


def _direct(u, h, skip=None, gate=None):
    from repro.core.fftconv import direct_causal_conv

    return direct_causal_conv(u, h, skip, gate)


def _blockfft(u, h, skip=None, gate=None):
    from repro.core import autotune
    from repro.core.blockfft import blockfft_causal_conv, factor_candidates
    from repro.core.fftconv import next_fast_len

    factors = None
    if autotune.mode() != "off":
        N = next_fast_len(2 * u.shape[1] - 1)

        def run(factors):
            import jax.numpy as jnp

            uu = jnp.ones(u.shape, u.dtype)
            hh = jnp.ones((u.shape[2], u.shape[1]), jnp.float32)
            return blockfft_causal_conv(uu, hh, factors=tuple(factors))

        plan = autotune.plan_for(
            "blockfft", u.shape, u.dtype,
            candidates=[{"factors": list(p)} for p in factor_candidates(N)],
            run=run,
        )
        if plan:
            factors = tuple(plan["factors"])
    return blockfft_causal_conv(u, h, skip, gate, factors=factors)


def _blockfft_overlap(u, h, skip=None, gate=None):
    from repro.core import autotune
    from repro.kernels.twolevel_fft import twolevel_candidates, twolevel_fft_conv

    kw = {}
    if autotune.mode() != "off":

        def run(factors=None, overlap=2, block_d=128):
            import jax.numpy as jnp

            uu = jnp.ones(u.shape, u.dtype)
            hh = jnp.ones((u.shape[2], u.shape[1]), jnp.float32)
            return twolevel_fft_conv(
                uu, hh,
                factors=tuple(factors) if factors else None,
                overlap=overlap, block_d=block_d,
            )

        plan = autotune.plan_for(
            "twolevel", u.shape, u.dtype,
            candidates=twolevel_candidates(u.shape),
            run=run,
        )
        if plan:
            kw = dict(plan)
            if "factors" in kw:
                kw["factors"] = tuple(kw["factors"])
    return twolevel_fft_conv(u, h, skip, gate, **kw)


def _toeplitz(u, h, skip=None, gate=None):
    from repro.kernels import ops as kops

    return kops.toeplitz_conv(u, h, skip, gate)


_FFT_SP_WARNED = False


def _fft_sp(u, h, skip=None, gate=None):
    # Sequence-parallel (context-parallel) FFT conv: L sharded over the cp
    # axis ('model' unless an ExecutionContext cp_axis scope names another),
    # two all-to-alls instead of an L-sized all-gather.  Non-divisible L is
    # padded to the next multiple inside sp_fft_causal_conv and the output
    # truncated (exact by causality) — it must NOT fall back to a
    # single-device full-L FFT, which is precisely the OOM this backend
    # exists to prevent.  Off-mesh (no ambient mesh / 1-way axis) it
    # degrades to the local FFT with a one-time warning, so the parity
    # sweep can still run it on one device.  Gate+skip are fused into the
    # post-conv elementwise inside the shard_map body (supports_gate=True).
    from repro.core.fftconv import fft_causal_conv
    from repro.distributed.ctx import current_cp_axis, current_mesh
    from repro.distributed.spconv import sp_fft_causal_conv

    mesh = current_mesh()
    axis = current_cp_axis() or "model"
    if mesh is None or mesh.shape.get(axis, 1) <= 1:
        global _FFT_SP_WARNED
        if not _FFT_SP_WARNED:
            _FFT_SP_WARNED = True
            import warnings

            warnings.warn(
                "conv backend 'fft_sp' selected without a sequence-parallel "
                f"mesh axis '{axis}' — running the single-device local FFT "
                "instead (full L per chip).",
                stacklevel=2,
            )
        return fft_causal_conv(u, h, skip, gate)
    return sp_fft_causal_conv(u, h, skip, mesh, axis=axis, gate=gate)


register_conv_backend(ConvBackend(
    name="fft", tag="shard_map_fft", fn=_fft, mesh_aware=True,
    supports_gate=True,
    description="O(L log L) real FFT on fast-composite >= 2L-1 points; "
    "shard_map-forced per-chip execution under a mesh, plain XLA FFT "
    "otherwise; gate+skip fused into the post-iFFT elementwise pass.",
))
register_conv_backend(ConvBackend(
    name="fft_local", tag="xla_fft", fn=_fft_local, supports_gate=True,
    description="single-device XLA FFT path (no shard_map), used as the "
    "oracle for the sharded variant.",
))
register_conv_backend(ConvBackend(
    name="direct", tag="toeplitz_oracle", fn=_direct, oracle=True,
    max_len=4096, supports_gate=True,
    description="O(L²) materialized lower-triangular Toeplitz matmul — "
    "the correctness oracle for tiny L.",
))
register_conv_backend(ConvBackend(
    name="blockfft", tag="matmul_dft", fn=_blockfft, supports_gate=True,
    description="four-step (Bailey) FFT with the small DFTs as dense "
    "matmuls — every FLOP on the MXU (H3-style block FFT); factor split "
    "autotunable (core.autotune).",
))
register_conv_backend(ConvBackend(
    name="blockfft_overlap", tag="twolevel_overlap", fn=_blockfft_overlap,
    supports_gate=True,
    description="overlapped two-level (inner R / outer S) FFT conv: one "
    "Pallas call pipelines inner-block DFT accumulation against HBM "
    "streaming and finalizes twiddle/outer-DFT/pointwise/inverse + the "
    "fused gate in VMEM (kernels/twolevel_fft.py); (R,S)/overlap/block_d "
    "autotunable as the 'twolevel' plan kind; off-TPU degrades to the "
    "identical-math blockfft schedule.",
))
register_conv_backend(ConvBackend(
    name="toeplitz", tag="pallas_mxu", fn=_toeplitz, requires_pallas=True,
    supports_gate=True,
    description="chunked block-Toeplitz Pallas MXU kernel (DESIGN.md §2); "
    "gate fused at kernel finalize in VMEM; interpret-mode off-TPU, jnp "
    "oracle on CPU.",
))
register_conv_backend(ConvBackend(
    name="fft_sp", tag="seqpar_fft", fn=_fft_sp, mesh_aware=True,
    supports_gate=True,
    description="sequence-parallel Cooley-Tukey FFT conv (context "
    "parallelism for 500K-token prefill AND training — differentiable via "
    "a custom VJP with the same two-all-to-all comm footprint): L sharded "
    "over the cp axis, padded to the next divisible length when needed; "
    "gate+skip fused in the shard_map epilogue; local-FFT fallback "
    "(warn-once) only when no mesh axis is available.",
))
