"""ConvBackend registry: the single dispatch point for Hyena's long
causal convolution (see DESIGN.md §2–3).

Every backend implements the same contract — ``fn(u, h, skip) -> y`` with
``u: (B, L, D)``, ``h: (D, L)``, ``skip: (D,) | None`` — plus capability
metadata used for *early* validation (at config/context construction, not
mid-forward) and for tooling (benchmarks iterate the registry instead of
hard-coding imports).

Adding a backend is one module + one ``register_conv_backend`` call; no
dispatch site anywhere else changes.  Backend resolution — including the
``REPRO_CONV_BACKEND`` environment override used by the launch layer — goes
through :func:`resolve_conv_backend`, the only place that env var is read.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional

ENV_VAR = "REPRO_CONV_BACKEND"
DEFAULT_BACKEND = "fft"


@dataclasses.dataclass(frozen=True)
class ConvBackend:
    """A registered long-conv implementation with capability flags.

    ``fn(u, h, skip)``: depthwise causal conv of ``u (B, L, D)`` with
    per-channel length-L filters ``h (D, L)`` and optional residual gain
    ``skip (D,)``.
    """

    name: str
    fn: Callable
    description: str = ""
    tag: str = ""  # short stable identifier for benchmark/report rows
    requires_pallas: bool = False  # Pallas lowering (interpret-mode off-TPU)
    mesh_aware: bool = False  # runs collective-free under a sharded mesh
    oracle: bool = False  # O(L²) reference — tests/tiny L only
    max_len: int = 0  # 0 = unconstrained; else largest supported L

    def validate_len(self, L: int) -> None:
        if self.max_len and L > self.max_len:
            raise ValueError(
                f"conv backend '{self.name}' supports L <= {self.max_len}, "
                f"got {L}"
            )

    def __call__(self, u, h, skip=None):
        return self.fn(u, h, skip)


_BACKENDS: Dict[str, ConvBackend] = {}


def register_conv_backend(backend: ConvBackend) -> ConvBackend:
    """Duplicate names raise unless the registration is identical — silent
    shadowing of e.g. 'fft' would swap the conv under every model."""
    prev = _BACKENDS.get(backend.name)
    if prev is not None and prev != backend:
        raise ValueError(f"conv backend '{backend.name}' already registered")
    _BACKENDS[backend.name] = backend
    return backend


def conv_backend_names() -> tuple:
    return tuple(sorted(_BACKENDS))


def registered_conv_backends() -> Dict[str, ConvBackend]:
    return dict(_BACKENDS)


def get_conv_backend(name: Optional[str]) -> ConvBackend:
    """Look up a backend; ``None`` means the registry default — the
    None-means-default rule lives here, not at dispatch sites."""
    name = name or DEFAULT_BACKEND
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown conv backend '{name}'; registered: "
            f"{list(conv_backend_names())}"
        )
    return _BACKENDS[name]


def resolve_conv_backend(
    override: Optional[str] = None, *, default: str = DEFAULT_BACKEND
) -> str:
    """One resolution point for the long-conv backend name.

    Priority: explicit ``override`` > ``$REPRO_CONV_BACKEND`` > ``default``.
    The resolved name is validated against the registry — unknown names
    raise immediately (config/launch time) with the registered list.
    """
    name = override or os.environ.get(ENV_VAR) or default
    get_conv_backend(name)
    return name


# --------------------------------------------------------------- built-ins
#
# The wrappers import lazily so that e.g. the Pallas toolchain is only
# touched when the 'toeplitz' backend is actually selected.

def _fft(u, h, skip=None):
    from repro.core.fftconv import fft_causal_conv_sharded

    return fft_causal_conv_sharded(u, h, skip)


def _fft_local(u, h, skip=None):
    from repro.core.fftconv import fft_causal_conv

    return fft_causal_conv(u, h, skip)


def _direct(u, h, skip=None):
    from repro.core.fftconv import direct_causal_conv

    return direct_causal_conv(u, h, skip)


def _blockfft(u, h, skip=None):
    from repro.core.blockfft import blockfft_causal_conv

    return blockfft_causal_conv(u, h, skip)


def _toeplitz(u, h, skip=None):
    from repro.kernels import ops as kops

    return kops.toeplitz_conv(u, h, skip)


register_conv_backend(ConvBackend(
    name="fft", tag="shard_map_fft", fn=_fft, mesh_aware=True,
    description="O(L log L) real FFT on 2L points; shard_map-forced "
    "per-chip execution under a mesh, plain XLA FFT otherwise.",
))
register_conv_backend(ConvBackend(
    name="fft_local", tag="xla_fft", fn=_fft_local,
    description="single-device XLA FFT path (no shard_map), used as the "
    "oracle for the sharded variant.",
))
register_conv_backend(ConvBackend(
    name="direct", tag="toeplitz_oracle", fn=_direct, oracle=True, max_len=4096,
    description="O(L²) materialized lower-triangular Toeplitz matmul — "
    "the correctness oracle for tiny L.",
))
register_conv_backend(ConvBackend(
    name="blockfft", tag="matmul_dft", fn=_blockfft,
    description="four-step (Bailey) FFT with the small DFTs as dense "
    "matmuls — every FLOP on the MXU (H3-style block FFT).",
))
register_conv_backend(ConvBackend(
    name="toeplitz", tag="pallas_mxu", fn=_toeplitz, requires_pallas=True,
    description="chunked block-Toeplitz Pallas MXU kernel (DESIGN.md §2); "
    "interpret-mode off-TPU, jnp oracle on CPU.",
))
