"""The order-N Hyena operator (paper Def. 3.1, Algorithms 1–3).

Forward pass (Algorithm 3), width D, order N, channel-last activations:

  1. Projection (Alg. 1): ``ẑ = Linear(u)`` with Linear: D → (N+1)·D, then a
     depthwise **short** causal conv (explicit FIR, width 3), then split into
     ``x¹..x^N, v``.
  2. Filters (Alg. 2): ``h¹..h^N`` from the implicit FFN parameterization
     (:mod:`repro.core.filters`).
  3. Recurrence: ``v ← x^n ⊙ FFTConv(h^n, v)`` for n = 1..N; output
     projection D → D.

Equivalently ``y = H(u)v`` with ``H(u) = D_x^N S_h^N ⋯ D_x^1 S_h^1`` — tested
against :mod:`repro.core.matrices`.  H3 == Hyena₂, GSS == Hyena₁ (Rmk 3.2).

The conv backend is pluggable through the :mod:`repro.core.conv_api`
registry: ``fft`` (default, O(L log L)), ``fft_local``, ``direct`` (O(L²)
oracle), ``blockfft`` (MXU four-step FFT), or ``toeplitz`` (Pallas chunked
block-Toeplitz MXU kernel — the TPU adaptation of the paper's fused CUDA
FFTConv; see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.core import filters as F
from repro.core.conv_api import get_conv_backend
from repro.core.fftconv import conv_cache_step, short_causal_conv


@dataclasses.dataclass(frozen=True)
class HyenaConfig:
    d_model: int
    order: int = 2
    short_filter_len: int = 3
    filter: F.FilterConfig = None  # type: ignore[assignment]
    use_bias: bool = True
    # NOTE: the long-conv backend is deliberately NOT part of this config —
    # it is an execution concern resolved exactly once, by the caller's
    # ApplyContext (repro.models.mixer_api) against repro.core.conv_api.

    def __post_init__(self):
        if self.filter is None:
            object.__setattr__(
                self, "filter", F.FilterConfig(d_model=self.d_model, order=self.order)
            )


def init_hyena(key, cfg: HyenaConfig) -> Dict[str, Any]:
    D, N = cfg.d_model, cfg.order
    k_in, k_out, k_short, k_filt = jax.random.split(key, 4)
    inner = (N + 1) * D
    params: Dict[str, Any] = {
        "in_proj": {
            "w": Ax(
                jax.random.normal(k_in, (D, inner), jnp.float32) / jnp.sqrt(D),
                ("embed", "hyena_inner"),
            ),
        },
        "out_proj": {
            "w": Ax(
                jax.random.normal(k_out, (D, D), jnp.float32) / jnp.sqrt(D),
                ("hyena_out", "embed"),
            ),
        },
        # short explicit depthwise filter over all (N+1)·D projected channels
        "short_filter": Ax(
            jax.random.normal(k_short, (inner, cfg.short_filter_len), jnp.float32)
            / jnp.sqrt(cfg.short_filter_len),
            ("hyena_inner", None),
        ),
        "filters": F.init_hyena_filter(k_filt, cfg.filter),
    }
    if cfg.use_bias:
        params["in_proj"]["b"] = Ax(jnp.zeros((inner,), jnp.float32), ("hyena_inner",))
        params["out_proj"]["b"] = Ax(jnp.zeros((D,), jnp.float32), ("embed",))
    return params


def _project(params, cfg: HyenaConfig, u: jax.Array):
    """Algorithm 1: linear → short depthwise causal conv → split."""
    B, L, D = u.shape
    N = cfg.order
    z = u @ params["in_proj"]["w"].astype(u.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(u.dtype)
    z = short_causal_conv(z, params["short_filter"])  # (B, L, (N+1)·D)
    parts = jnp.split(z, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    return v, xs


def hyena_operator(
    params, cfg: HyenaConfig, u: jax.Array, *, conv_backend: Optional[str] = None
) -> jax.Array:
    """y = Hyena_N(u), u: (B, L, D) -> (B, L, D).

    ``conv_backend`` names a :mod:`repro.core.conv_api` registration
    (default ``"fft"``); unknown names raise here, before any tracing.
    """
    B, L, D = u.shape
    backend = get_conv_backend(conv_backend)
    backend.validate_len(L)
    v, xs = _project(params, cfg, u)
    h = F.evaluate_filters(params["filters"], cfg.filter, L)  # (N, D, L)
    skip = F.filter_skip(params["filters"], cfg.filter)  # (N, D)
    for n in range(cfg.order):
        v = xs[n] * backend(v, h[n], skip[n]).astype(u.dtype)
    y = v @ params["out_proj"]["w"].astype(u.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(u.dtype)
    return y


# ---------------------------------------------------------------------------
# Decode path: O(L_cache) per token via cached projected inputs.
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: HyenaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Rolling caches for single-token decode.

    - ``short``: last (short_filter_len - 1) projected inputs, per channel.
    - ``long``: last ``max_len`` values of the recurrence operand ``z^n`` for
      every order (the conv input at order n), newest-first.
    """
    D, N = cfg.d_model, cfg.order
    inner = (N + 1) * D
    return {
        "short": jnp.zeros((batch, cfg.short_filter_len - 1, inner), dtype),
        "long": jnp.zeros((N, batch, max_len, D), dtype),
        # per-row position counter (continuous batching: one request per row)
        "t": jnp.zeros((batch,), jnp.int32),
    }


def hyena_decode_step(
    params, cfg: HyenaConfig, u_t: jax.Array, cache: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token: u_t (B, D) -> y_t (B, D), updated cache.

    Matches ``hyena_operator`` teacher-forced outputs (tested): the long conv
    is evaluated as an explicit dot against the cached operand history, the
    filter taps being re-evaluated (cheap: one FFN pass over L grid points is
    *not* needed per step — taps are evaluated once per sequence by the
    caller via ``precompute_decode_filters`` and passed in the cache).
    """
    B, Dm = u_t.shape
    N = cfg.order
    Lc = cache["long"].shape[2]
    h = cache.get("h")
    skip = cache.get("skip")
    if h is None:
        h = F.evaluate_filters(params["filters"], cfg.filter, Lc)
        skip = F.filter_skip(params["filters"], cfg.filter)
    # --- projection + short conv (explicit taps over a tiny rolling window)
    z = u_t @ params["in_proj"]["w"].astype(u_t.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(u_t.dtype)
    w = params["short_filter"]  # (inner, K)
    hist = cache["short"]  # (B, K-1, inner) newest-first
    zc = z.astype(jnp.float32) * w[:, 0].astype(jnp.float32)[None, :]
    for k in range(1, cfg.short_filter_len):
        zc = zc + hist[:, k - 1].astype(jnp.float32) * w[:, k].astype(jnp.float32)[None, :]
    new_short = jnp.concatenate(
        [z[:, None, :], hist[:, : cfg.short_filter_len - 2]], axis=1
    )
    zc = zc.astype(u_t.dtype)
    parts = jnp.split(zc, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    # --- recurrence with per-order conv caches
    new_long = []
    for n in range(N):
        conv_y, new_cache_n = conv_cache_step(cache["long"][n], v, h[n], skip[n])
        new_long.append(new_cache_n)
        v = xs[n] * conv_y.astype(u_t.dtype)
    y = v @ params["out_proj"]["w"].astype(u_t.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(u_t.dtype)
    out_cache = dict(cache)
    out_cache.update(
        {"short": new_short, "long": jnp.stack(new_long), "t": cache["t"] + 1}
    )
    return y, out_cache


def precompute_decode_filters(params, cfg: HyenaConfig, max_len: int, cache):
    """Evaluate filter taps once per sequence and stash them in the cache."""
    cache = dict(cache)
    cache["h"] = F.evaluate_filters(params["filters"], cfg.filter, max_len)
    cache["skip"] = F.filter_skip(params["filters"], cfg.filter)
    return cache
