"""The order-N Hyena operator (paper Def. 3.1, Algorithms 1–3).

Forward pass (Algorithm 3), width D, order N, channel-last activations:

  1. Projection (Alg. 1): ``ẑ = Linear(u)`` with Linear: D → (N+1)·D, then a
     depthwise **short** causal conv (explicit FIR, width 3), then split into
     ``x¹..x^N, v``.
  2. Filters (Alg. 2): ``h¹..h^N`` from the implicit FFN parameterization
     (:mod:`repro.core.filters`).
  3. Recurrence: ``v ← x^n ⊙ FFTConv(h^n, v)`` for n = 1..N; output
     projection D → D.  The gate ``x^n ⊙`` is *fused into the conv backend*
     (conv_api's gated contract, DESIGN.md §7): the operator never runs a
     standalone full-tensor gate multiply.

Equivalently ``y = H(u)v`` with ``H(u) = D_x^N S_h^N ⋯ D_x^1 S_h^1`` — tested
against :mod:`repro.core.matrices`.  H3 == Hyena₂, GSS == Hyena₁ (Rmk 3.2).

The conv backend is pluggable through the :mod:`repro.core.conv_api`
registry: ``fft`` (default, O(L log L)), ``fft_local``, ``direct`` (O(L²)
oracle), ``blockfft`` (MXU four-step FFT), or ``toeplitz`` (Pallas chunked
block-Toeplitz MXU kernel — the TPU adaptation of the paper's fused CUDA
FFTConv; see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.param import Ax
from repro.core import filters as F
from repro.core.conv_api import get_conv_backend
from repro.core.fftconv import short_causal_conv


@dataclasses.dataclass(frozen=True)
class HyenaConfig:
    d_model: int
    order: int = 2
    short_filter_len: int = 3
    filter: F.FilterConfig = None  # type: ignore[assignment]
    use_bias: bool = True
    # NOTE: the long-conv backend is deliberately NOT part of this config —
    # it is an execution concern resolved exactly once, by the caller's
    # ApplyContext (repro.models.mixer_api) against repro.core.conv_api.

    def __post_init__(self):
        if self.filter is None:
            object.__setattr__(
                self, "filter", F.FilterConfig(d_model=self.d_model, order=self.order)
            )


def init_hyena(key, cfg: HyenaConfig) -> Dict[str, Any]:
    D, N = cfg.d_model, cfg.order
    k_in, k_out, k_short, k_filt = jax.random.split(key, 4)
    inner = (N + 1) * D
    params: Dict[str, Any] = {
        "in_proj": {
            "w": Ax(
                jax.random.normal(k_in, (D, inner), jnp.float32) / jnp.sqrt(D),
                ("embed", "hyena_inner"),
            ),
        },
        "out_proj": {
            "w": Ax(
                jax.random.normal(k_out, (D, D), jnp.float32) / jnp.sqrt(D),
                ("hyena_out", "embed"),
            ),
        },
        # short explicit depthwise filter over all (N+1)·D projected channels
        "short_filter": Ax(
            jax.random.normal(k_short, (inner, cfg.short_filter_len), jnp.float32)
            / jnp.sqrt(cfg.short_filter_len),
            ("hyena_inner", None),
        ),
        "filters": F.init_hyena_filter(k_filt, cfg.filter),
    }
    if cfg.use_bias:
        params["in_proj"]["b"] = Ax(jnp.zeros((inner,), jnp.float32), ("hyena_inner",))
        params["out_proj"]["b"] = Ax(jnp.zeros((D,), jnp.float32), ("embed",))
    return params


def _project(params, cfg: HyenaConfig, u: jax.Array):
    """Algorithm 1: linear → short depthwise causal conv → split."""
    B, L, D = u.shape
    N = cfg.order
    z = u @ params["in_proj"]["w"].astype(u.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(u.dtype)
    z = short_causal_conv(z, params["short_filter"])  # (B, L, (N+1)·D)
    parts = jnp.split(z, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    return v, xs


def hyena_operator(
    params, cfg: HyenaConfig, u: jax.Array, *, conv_backend: Optional[str] = None
) -> jax.Array:
    """y = Hyena_N(u), u: (B, L, D) -> (B, L, D).

    ``conv_backend`` names a :mod:`repro.core.conv_api` registration
    (default ``"fft"``); unknown names raise here, before any tracing.
    """
    B, L, D = u.shape
    backend = get_conv_backend(conv_backend)
    backend.validate_len(L)
    v, xs = _project(params, cfg, u)
    h = F.evaluate_filters(params["filters"], cfg.filter, L)  # (N, D, L)
    skip = F.filter_skip(params["filters"], cfg.filter)  # (N, D)
    for n in range(cfg.order):
        v = backend(v, h[n], skip[n], gate=xs[n]).astype(u.dtype)
    y = v @ params["out_proj"]["w"].astype(u.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(u.dtype)
    return y


# ---------------------------------------------------------------------------
# Decode path: O(L_cache) per token via cached projected inputs.
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: HyenaConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Caches for single-token decode.

    - ``short``: last (short_filter_len - 1) projected inputs, per channel,
      newest-first (a tiny rolling window).
    - ``long``: the recurrence operand ``z^n`` for every order (the conv
      input at order n) stored at its **absolute position**: the value fed
      at step ``p`` lives at index ``p`` and is never moved again.  One
      dynamic write per token (no O(max_len) shift), so the history can
      live in copy-on-write paged blocks (``repro.serve.paged``) without
      dirtying every page on every step.  Positions ``>= t`` are unwritten
      (zero or stale) and masked out of the decode contraction.
    """
    D, N = cfg.d_model, cfg.order
    inner = (N + 1) * D
    return {
        "short": jnp.zeros((batch, cfg.short_filter_len - 1, inner), dtype),
        "long": jnp.zeros((N, batch, max_len, D), dtype),
        # per-row position counter (continuous batching: one request per row)
        "t": jnp.zeros((batch,), jnp.int32),
    }


# One-time host-side memo for callers that forgot precompute_decode_filters:
# the taps of a given (filter params, cfg.filter, L_cache) are evaluated on
# the FIRST fallback decode step and reused for every later token, instead of
# re-running the full filter FFN over the whole cache grid per token (a
# serving-latency cliff).  Keyed by param-leaf ids with a weakref eviction
# hook (jax arrays are weakref-able but not hashable) so updated / freed
# params drop their taps; the cache treedef is untouched (the mixer contract
# requires decode_step to preserve it for lax.scan).
_FALLBACK_TAPS: Dict[tuple, tuple] = {}


def _fallback_decode_taps(params, cfg: HyenaConfig, Lc: int):
    leaves = jax.tree_util.tree_leaves(params["filters"])
    if not leaves or any(isinstance(l, jax.core.Tracer) for l in leaves):
        # traced decode paths must precompute (prefill does); evaluating
        # here would bake the FFN into every unrolled/scanned step
        return (
            F.evaluate_filters(params["filters"], cfg.filter, Lc),
            F.filter_skip(params["filters"], cfg.filter),
        )
    key = (cfg.filter, Lc, tuple(id(l) for l in leaves))
    hit = _FALLBACK_TAPS.get(key)
    if hit is not None and all(
        r() is l for r, l in zip(hit[0], leaves)
    ):  # id-reuse guard: EVERY leaf must still be the object we memoized
        return hit[1]
    taps = (
        F.evaluate_filters(params["filters"], cfg.filter, Lc),
        F.filter_skip(params["filters"], cfg.filter),
    )
    evict = lambda _, k=key: _FALLBACK_TAPS.pop(k, None)
    _FALLBACK_TAPS[key] = (
        tuple(weakref.ref(l, evict) for l in leaves),
        taps,
    )
    return taps


def hyena_decode_step(
    params, cfg: HyenaConfig, u_t: jax.Array, cache: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token: u_t (B, D) -> y_t (B, D), updated cache.

    Matches ``hyena_operator`` teacher-forced outputs (tested).  The cache
    holds each order's operand at its absolute position (append-only, see
    :func:`init_decode_cache`), so with per-row cursor ``t``

        y^n_t = (h^n_0 + skip^n)·v^n_t + Σ_{p<t} h^n_{t-p}·v^n_p

    The lag taps ``h^n_{t-p}`` for ``p = 0..Lc-1`` are one contiguous slice
    of the reversed tap grid starting at ``Th-1-t`` (per row, a
    dynamic_slice); positions ``p >= t`` — unwritten or stale from a
    recycled page — are masked to zero, so the history term tolerates
    arbitrary garbage past the cursor.  All N orders then contract in one
    stacked fp32 einsum; only the cheap rank-1 correction
    ``(h^n_0 + skip^n)·v`` stays inside the sequential order loop.

    The cache length ``Lc`` may be SHORTER than the tap grid ``Th`` (a
    paged engine gathers a view just covering the live prefix); the only
    requirement is ``t < min(Lc + 1, Th)`` — positions and taps past the
    view are out of contract, exactly like decoding past ``max_len``.

    Filter taps should be precomputed (``precompute_decode_filters`` /
    mixer prefill).  A cache without taps falls back to a ONE-TIME
    host-side evaluation (memoized per filter params × cache length) —
    never the old per-token filter-FFN re-evaluation cliff.
    """
    B, Dm = u_t.shape
    N = cfg.order
    Lc = cache["long"].shape[2]
    h = cache.get("h")
    skip = cache.get("skip")
    if h is None:
        h, skip = _fallback_decode_taps(params, cfg, Lc)
    # --- projection + short conv (explicit taps over a tiny rolling window)
    z = u_t @ params["in_proj"]["w"].astype(u_t.dtype)
    if "b" in params["in_proj"]:
        z = z + params["in_proj"]["b"].astype(u_t.dtype)
    w = params["short_filter"]  # (inner, K)
    hist = cache["short"]  # (B, K-1, inner) newest-first
    zc = z.astype(jnp.float32) * w[:, 0].astype(jnp.float32)[None, :]
    for k in range(1, cfg.short_filter_len):
        zc = zc + hist[:, k - 1].astype(jnp.float32) * w[:, k].astype(jnp.float32)[None, :]
    new_short = jnp.concatenate(
        [z[:, None, :], hist[:, : cfg.short_filter_len - 2]], axis=1
    )
    zc = zc.astype(u_t.dtype)
    parts = jnp.split(zc, N + 1, axis=-1)
    v, xs = parts[0], parts[1:]
    # --- recurrence: one stacked history contraction for all orders over
    # the absolute-position operand cache.  The Σ_{p<t} term (the expensive
    # O(N·B·Lc·D) part) only reads the cache, never the current v^n, so all
    # orders share one einsum; per-row lag taps are a dynamic_slice of the
    # reversed grid, masked past the cursor.
    t = cache["t"]  # (B,) per-row absolute position (== tokens absorbed)
    Th = h.shape[2]
    hist32 = cache["long"].astype(jnp.float32)  # (N, B, Lc, D)
    h_rev = jnp.flip(h, axis=2).astype(jnp.float32)  # (N, D, Th)
    h_ext = jnp.pad(h_rev, ((0, 0), (0, 0), (0, Lc)))

    def row_taps(tb):
        # taps[p] = h[t - p] for p < t, else 0: slice of the reversed grid
        a = jax.lax.dynamic_slice(h_ext, (0, 0, Th - 1 - tb), (N, Dm, Lc))
        return a * (jnp.arange(Lc) < tb)[None, None, :]

    taps = jax.vmap(row_taps)(t)  # (B, N, D, Lc) fp32
    hist = jnp.einsum("nbpd,bndp->nbd", hist32, taps)  # fp32 accumulate
    h0 = (h[:, :, 0] + skip).astype(jnp.float32)  # (N, D) fused rank-1 taps
    ldtype = cache["long"].dtype
    vs = []
    for n in range(N):
        vs.append(v.astype(ldtype))
        conv_y = hist[n] + v.astype(jnp.float32) * h0[n][None, :]
        v = xs[n] * conv_y.astype(u_t.dtype)
    y = v @ params["out_proj"]["w"].astype(u_t.dtype)
    if "b" in params["out_proj"]:
        y = y + params["out_proj"]["b"].astype(u_t.dtype)
    rows = jnp.arange(B)
    new_long = cache["long"].at[:, rows, t].set(jnp.stack(vs))
    out_cache = dict(cache)
    out_cache.update(
        {"short": new_short, "long": new_long, "t": cache["t"] + 1}
    )
    return y, out_cache


def precompute_decode_filters(params, cfg: HyenaConfig, max_len: int, cache):
    """Evaluate filter taps once per sequence and stash them in the cache."""
    cache = dict(cache)
    cache["h"] = F.evaluate_filters(params["filters"], cfg.filter, max_len)
    cache["skip"] = F.filter_skip(params["filters"], cfg.filter)
    return cache
