from repro.core.filters import FilterConfig, init_hyena_filter, evaluate_filters  # noqa: F401
from repro.core.operator import (  # noqa: F401
    HyenaConfig,
    init_hyena,
    hyena_operator,
    hyena_decode_step,
    init_decode_cache,
    precompute_decode_filters,
)
from repro.core.fftconv import (  # noqa: F401
    fft_causal_conv,
    direct_causal_conv,
    short_causal_conv,
    conv_cache_step,
)
from repro.core.conv_api import (  # noqa: F401
    ConvBackend,
    get_conv_backend,
    register_conv_backend,
    registered_conv_backends,
    resolve_conv_backend,
)
