"""Causal long convolution via FFT (paper §2.1 "Fast Methods for
Convolutions", Prop. 3.1 causality note).

The aperiodic causal convolution ``y_t = Σ_{n≤t} h_{t-n} u_n`` is evaluated
by zero-padding input and filter to ``2L`` and multiplying in the frequency
domain — ``iFFT(D_H FFT(pad(u)))`` — in ``O(L log L)``.  Causality holds
because the filter is evaluated at ``t = 0..L-1`` only and the padding
prevents circular wrap-around (paper: "all we need is to evaluate the filter
at t=0,…,L−1 and zero-pad ... to 2L−1 before taking FFT").

FFT always runs in fp32 (bf16 FFT loses too much precision over long
reductions); inputs/outputs keep their dtype.

The optional ``gate`` argument fuses the Hyena recurrence's data-controlled
gate ``xⁿ ⊙ conv(v)`` into the single post-iFFT elementwise expression —
skip-add and gate-multiply happen in fp32 before the downcast, in one pass
over the tensor instead of a separate full-tensor multiply (DESIGN.md §7).

Layouts: activations are channel-last ``(B, L, D)``; filters ``(D, L)``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def next_fast_len(n: int) -> int:
    """Smallest 5-smooth (2^a·3^b·5^c) integer >= n.

    ``jnp.fft`` plans degrade badly on lengths with large prime factors;
    padding the conv's ``fft_size`` up to the next fast composite keeps odd
    and prime-ish L off the worst-case DFT path at the cost of a few extra
    (already-zero-padded) points."""
    if n <= 1:
        return 1
    best = 1 << (n - 1).bit_length()  # next power of two is always valid
    f5 = 1
    while f5 < best:
        f35 = f5
        while f35 < best:
            f = f35
            while f < n:
                f *= 2
            if f < best:
                best = f
            f35 *= 3
        f5 *= 5
    return best


def _fused_epilogue(y, u32, skip, gate, dtype):
    """One elementwise pass: y (+ skip·u) in fp32, downcast, then (· gate)
    in the output dtype.

    The gate multiplies the *downcast* conv output on purpose: fusion must
    be a pure memory-traffic optimization, bit-identical to the two-pass
    schedule ``gate * conv(u)`` it replaces — keeping fp32 through the gate
    would be more precise but would make enabling fusion change bf16 model
    outputs (DESIGN.md §7)."""
    if skip is not None:
        y = y + u32 * skip[None, None, :].astype(jnp.float32)
    y = y.astype(dtype)
    if gate is not None:
        y = y * gate.astype(dtype)
    return y


def fft_causal_conv(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L)
    skip: Optional[jax.Array] = None,  # (D,) residual gain: y += skip * u
    gate: Optional[jax.Array] = None,  # (B, L, D) elementwise output gate
) -> jax.Array:
    """Depthwise causal convolution of every channel with its own length-L
    filter, via real FFT on ``next_fast_len(2L - 1)`` points."""
    B, L, D = u.shape
    assert h.shape == (D, L), (h.shape, (D, L))
    # linear conv of two length-L signals has support 2L-1; any fft_size
    # >= 2L-1 keeps the first L outputs free of circular wrap-around, so
    # the truncation back to L is exact (the padding only adds zeros).
    fft_size = next_fast_len(2 * L - 1)
    assert fft_size >= 2 * L - 1, (fft_size, L)
    dtype = u.dtype
    u32 = u.astype(jnp.float32)
    h32 = h.astype(jnp.float32)
    U = jnp.fft.rfft(u32, n=fft_size, axis=1)  # (B, F, D)
    H = jnp.fft.rfft(h32, n=fft_size, axis=1).T  # (F, D)
    y = jnp.fft.irfft(U * H[None], n=fft_size, axis=1)[:, :L, :]
    return _fused_epilogue(y, u32, skip, gate, dtype)


def fft_causal_conv_sharded(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L)
    skip: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,  # (B, L, D), same layout as u
) -> jax.Array:
    """FFT conv under shard_map: the XLA SPMD partitioner cannot partition
    the FFT custom-call — sharding constraints around it only relocate a
    full all-gather of the activation (measured 260 GB/chip/layer in the
    dry-run baseline).  Hyena's long conv is depthwise, so forcing
    per-shard execution with shard_map (batch on data axes, channels on
    model) removes that traffic entirely: zero collectives inside the conv
    (EXPERIMENTS.md §Perf pair A).
    """
    from repro.distributed.ctx import current_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    B, L, D = u.shape
    if mesh is None:
        return fft_causal_conv(u, h, skip, gate)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_sz = 1
    for a in data_axes:
        data_sz *= mesh.shape[a]
    model = "model" if "model" in mesh.shape else None
    model_sz = mesh.shape.get("model", 1)
    if (data_axes and B % data_sz) or (model and D % model_sz):
        return fft_causal_conv(u, h, skip, gate)
    bspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    skip_in = skip if skip is not None else jnp.zeros((D,), jnp.float32)
    act_spec = P(bspec, None, model)
    if gate is None:
        fn = shard_map(
            lambda ub, hb, sb: fft_causal_conv(ub, hb, sb),
            mesh=mesh,
            in_specs=(act_spec, P(model, None), P(model)),
            out_specs=act_spec,
            check=False,  # FFT transpose rule trips the vma checker under AD
        )
        return fn(u, h, skip_in)
    # the gate shares u's activation layout, so fusing it keeps the conv
    # collective-free: the gate multiply happens on the shard, per chip
    fn = shard_map(
        lambda ub, hb, sb, gb: fft_causal_conv(ub, hb, sb, gb),
        mesh=mesh,
        in_specs=(act_spec, P(model, None), P(model), act_spec),
        out_specs=act_spec,
        check=False,
    )
    return fn(u, h, skip_in, gate)


def direct_causal_conv(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L)
    skip: Optional[jax.Array] = None,
    gate: Optional[jax.Array] = None,  # (B, L, D)
) -> jax.Array:
    """O(L²) reference: materializes the lower-triangular Toeplitz matmul.

    Used as the oracle in tests and for tiny L.
    """
    B, L, D = u.shape
    t = jnp.arange(L)
    idx = t[:, None] - t[None, :]  # (L, L), h index; negative => acausal
    mask = idx >= 0
    # S[d, i, j] = h[d, i - j] for i >= j else 0
    S = jnp.where(mask[None], h[:, jnp.clip(idx, 0, L - 1)], 0.0)  # (D, L, L)
    u32 = u.astype(jnp.float32)
    y = jnp.einsum("dij,bjd->bid", S.astype(jnp.float32), u32)
    return _fused_epilogue(y, u32, skip, gate, u.dtype)


def short_causal_conv(
    u: jax.Array,  # (B, L, D)
    w: jax.Array,  # (D, K) short explicit filter (K ~ 3/4)
    bias: Optional[jax.Array] = None,  # (D,)
) -> jax.Array:
    """Depthwise causal FIR conv with a short explicit filter (Alg. 1 step 2).

    ``y_t = Σ_{k<K} w_k · u_{t-k}`` — implemented as K shifted adds (cheap,
    fuses well under XLA; the Pallas kernel version lives in repro.kernels).
    """
    B, L, D = u.shape
    K = w.shape[1]
    y = jnp.zeros_like(u, dtype=jnp.float32)
    u32 = u.astype(jnp.float32)
    for k in range(K):
        shifted = u32 if k == 0 else jnp.pad(u32, ((0, 0), (k, 0), (0, 0)))[:, :L]
        y = y + shifted * w[:, k][None, None, :].astype(jnp.float32)
    if bias is not None:
        y = y + bias[None, None, :].astype(jnp.float32)
    return y.astype(u.dtype)


def conv_cache_step(
    cache: jax.Array,  # (B, L_cache, D) rolling buffer of past inputs
    u_t: jax.Array,  # (B, D) new input at the current step
    h: jax.Array,  # (D, L) filter (only first L_cache+ taps used)
    skip: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Single-token decode for a long conv: O(L_cache·D) dot with cached
    inputs.  Cache layout: cache[:, 0] is the *newest* element (time t), so
    ``y_t = Σ_n h_n · u_{t-n} = Σ_n h_n · cache[:, n]``.

    Reference semantics for one order: the production decode path
    (``operator.hyena_decode_step``) evaluates all N orders' history dots
    in one stacked dot_general instead of calling this per order, but must
    stay numerically equivalent to it (pinned by the decode-parity tests).

    Returns (y_t (B, D), new_cache).
    """
    B, Lc, D = cache.shape
    cache = jnp.concatenate([u_t[:, None, :], cache[:, : Lc - 1]], axis=1)
    taps = h[:, :Lc].astype(jnp.float32)  # (D, Lc)
    y = jnp.einsum("bld,dl->bd", cache.astype(jnp.float32), taps)
    if skip is not None:
        y = y + u_t.astype(jnp.float32) * skip[None, :].astype(jnp.float32)
    return y.astype(u_t.dtype), cache
