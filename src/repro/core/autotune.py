"""Autotuned conv-plan cache (DESIGN.md §8).

Every Pallas conv kernel and the blockfft backend have shape-sensitive tile
parameters (``block_l``/``block_d`` for the short conv, ``chunk``/``block_d``
for the Toeplitz kernel, the (R, S) factor split for the four-step FFT).
Hand-picked defaults are wrong somewhere; this module replaces them with a
per-``(kind, B, L, D, dtype, platform)`` *plan*: a small dict of tile
parameters that was timed-searched once and persisted, so model code never
names a tile size (`repro.kernels.ops` consults the cache at dispatch).
The platform is part of the key so tiles timed on one device class (or the
CPU interpreter) are never served to another.

Mode is controlled by ``$REPRO_AUTOTUNE``:

  * ``off``   (default) — plans are never consulted; kernel defaults apply.
  * ``search`` — cache miss triggers a timed search over the caller's
    candidate list (synthetic inputs at the real shape, best wall-clock
    wins); the winner is persisted to the plan file and reused.
  * ``load``  — plans are read from the plan file; a missing entry falls
    back to kernel defaults (never searches — safe for serving, where a
    surprise multi-second search on the first request of a new shape is an
    outage, not an optimization).

The plan file (``$REPRO_AUTOTUNE_FILE``, default
``~/.cache/repro/conv_plans.json``) is a flat JSON object
``{plan_key: {param: value}}`` — human-diffable, written atomically
(temp file + rename), and tolerant of corruption (a bad file is treated as
empty rather than taking the model down).

Plans are *semantics-preserving by construction*: candidate lists only ever
contain parameter points that compute the identical convolution (tile sizes,
factor splits).  Approximation knobs — the Toeplitz kernel's banded
``n_chunk_diags`` — are part of the plan **key**, chosen by the caller, and
never searched over.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Any, Callable, Dict, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

ENV_MODE = "REPRO_AUTOTUNE"
ENV_FILE = "REPRO_AUTOTUNE_FILE"
MODES = ("off", "search", "load")
_DEFAULT_FILE = os.path.join("~", ".cache", "repro", "conv_plans.json")

_lock = threading.Lock()
# in-memory mirror of the plan file, keyed by resolved path (the env var can
# change between calls — tests point it at tmp dirs); each entry carries the
# file's (mtime_ns, size) signature so a plan file written by ANOTHER
# process after our first read (offline searcher feeding a load-mode
# server) is picked up without a restart
_mem: Dict[str, tuple] = {}


def mode() -> str:
    m = os.environ.get(ENV_MODE, "off") or "off"
    if m not in MODES:
        raise ValueError(
            f"${ENV_MODE}={m!r}; expected one of {MODES}"
        )
    return m


def plan_file() -> str:
    return os.path.expanduser(os.environ.get(ENV_FILE) or _DEFAULT_FILE)


def plan_key(kind: str, shape: Sequence[int], dtype) -> str:
    # the platform is part of the key: tiles timed on one device class
    # (worse: the Pallas *interpreter* on CPU) must never be served to
    # another — the shared default plan file makes that cross-talk easy
    B, L, D = shape
    return (
        f"{kind}:B{B}:L{L}:D{D}:{jnp.dtype(dtype).name}"
        f":{jax.default_backend()}"
    )


def reset_cache() -> None:
    """Drop the in-memory mirror (tests switch plan files mid-process)."""
    with _lock:
        _mem.clear()


def _file_sig(path: str):
    try:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None  # missing file


def _load(path: str) -> Dict[str, Dict[str, Any]]:
    sig = _file_sig(path)
    hit = _mem.get(path)
    if hit is not None and hit[0] == sig:
        return hit[1]
    plans: Dict[str, Dict[str, Any]] = {}
    try:
        with open(path) as f:
            raw = json.load(f)
        if isinstance(raw, dict):
            plans = {
                k: dict(v) for k, v in raw.items() if isinstance(v, dict)
            }
    except (OSError, ValueError):
        pass  # missing or corrupt plan file == no plans
    _mem[path] = (sig, plans)
    return plans


def _persist(path: str, plans: Dict[str, Dict[str, Any]]) -> None:
    """Merge-then-replace: re-read the file so concurrent searchers (other
    processes sharing the plan file) don't have their fresh keys clobbered
    by this process's stale in-memory mirror; last writer wins per-key
    only, never per-file."""
    _mem.pop(path, None)
    merged = dict(_load(path))
    merged.update(plans)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".plans")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _mem[path] = (_file_sig(path), merged)


def _time_once(fn: Callable[[], Any], iters: int = 3) -> float:
    jax.block_until_ready(fn())  # compile + warm-up
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def search(
    candidates: Iterable[Dict[str, Any]],
    run: Callable[..., Any],
) -> Optional[Dict[str, Any]]:
    """Best-wall-clock candidate (min over iters); raising candidates are
    skipped (e.g. a tile that doesn't divide the shape)."""
    best, best_t = None, float("inf")
    for cand in candidates:
        try:
            t = _time_once(lambda: run(**cand))
        except Exception:
            continue
        if t < best_t:
            best, best_t = dict(cand), t
    return best


def plan_for(
    kind: str,
    shape: Sequence[int],
    dtype,
    *,
    candidates: Sequence[Dict[str, Any]],
    run: Callable[..., Any],
) -> Optional[Dict[str, Any]]:
    """The one entry point kernels dispatch through.

    Returns the plan dict for ``(kind, shape, dtype)`` or ``None`` (use the
    kernel's defaults).  ``run(**candidate)`` must execute the kernel on
    *synthetic* inputs of the given shape — it is called (and timed) only in
    ``search`` mode on a cache miss, and must not close over tracers (plans
    are consulted from inside jit traces, where timing the traced values
    would be meaningless).
    """
    m = mode()
    if m == "off" or not candidates:
        return None
    # a plan is only usable if the kernel knows its params: keys outside
    # the candidate vocabulary (schema drift, hand-edited file) are
    # dropped so a stale plan file degrades to defaults instead of a
    # TypeError on the first request of a shape — load is serving-safe
    allowed = set()
    for c in candidates:
        allowed.update(c)
    path = plan_file()
    key = plan_key(kind, shape, dtype)
    with _lock:
        plans = _load(path)
        if key in plans:
            plan = {k: v for k, v in plans[key].items() if k in allowed}
            return plan or None
        if m != "search":
            return None
    best = search(candidates, run)
    if best is None:
        return None
    with _lock:
        plans = dict(_load(path))
        plans.setdefault(key, best)
        _persist(path, plans)
        return {k: v for k, v in plans[key].items() if k in allowed}
