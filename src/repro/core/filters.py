"""Implicit Hyena filter parameterization (paper §3.3, Alg. 2, App. D.3).

A filter bank ``h ∈ R^{order × D × L}`` is produced by:

  1. ``PositionalEncoding(t)`` — truncated complex-exponential basis
     (App. D.3): ``[t, Re ρ_0..Re ρ_{K-1}, Im ρ_0..Im ρ_{K-1}]`` with
     ``ρ_k(t) = exp(i 2π k t / L)`` — dimension ``D_e = 2K + 1``.
  2. A shallow FFN with **sine** activations ``σ(x) = sin(ω x)`` (sine freq
     ``ω = 14`` in the paper's LM configs, Table A.4) mapping
     ``R^{D_e} → R^{order·D}``.
  3. An **exponential-decay window** with per-channel rates plus a learnable
     bias shift (Fig. 3.1: the bias keeps filters from being forced to zero
     past the decay horizon).

Parameter count is independent of L — the paper's *sublinear parameter
scaling* property.  Filters are evaluated once per forward pass, in parallel
across (order, D, L) — Algorithm 2.

Static hyper-parameters live in :class:`FilterConfig`; the param pytree holds
arrays only (jit-safe).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.common.param import Ax


@dataclasses.dataclass(frozen=True)
class FilterConfig:
    d_model: int
    order: int
    ffn_width: int = 64
    ffn_depth: int = 4  # number of linear layers (>= 2)
    pos_dim: int = 65  # 2K + 1
    sine_freq: float = 14.0
    decay_fast: float = 0.3
    decay_slow: float = 1.5
    normalized: bool = True
    max_support: int = 0  # >0: hard-truncate taps at this lag (explicit-FIR
    # ablation — the paper's Conv1d baseline with filter size M)


def positional_encoding(L: int, pos_dim: int, dtype=jnp.float32) -> jax.Array:
    """(L, pos_dim) truncated complex-exponential basis. pos_dim = 2K + 1."""
    K = (pos_dim - 1) // 2
    t = jnp.linspace(0.0, 1.0, L, dtype=jnp.float32)[:, None]  # (L, 1)
    if K == 0:
        return t.astype(dtype)
    k = jnp.arange(K, dtype=jnp.float32)[None, :]  # (1, K)
    ang = 2.0 * math.pi * k * t  # (L, K) — ρ_k(t) = exp(i·ang)
    z = jnp.concatenate([t, jnp.cos(ang), jnp.sin(ang)], axis=-1)
    return z.astype(dtype)


def init_hyena_filter(key, cfg: FilterConfig) -> Dict[str, Any]:
    """Params for the implicit filter FFN + window.

    Decay rates are log-spaced across channels at init ("Parameter α is
    modified across the independent channels ... to regularize filters to be
    of different lengths") and trainable.
    """
    assert cfg.ffn_depth >= 2
    dims = [cfg.pos_dim] + [cfg.ffn_width] * (cfg.ffn_depth - 1) + [
        cfg.order * cfg.d_model
    ]
    keys = jax.random.split(key, len(dims))
    layers = []
    for i in range(len(dims) - 1):
        w = jax.random.normal(keys[i], (dims[i], dims[i + 1]), jnp.float32)
        w = w / math.sqrt(dims[i])
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        out_ax = "hyena_channels" if i == len(dims) - 2 else None
        layers.append({"w": Ax(w, (None, out_ax)), "b": Ax(b, (out_ax,))})
    n_ch = cfg.order * cfg.d_model
    log_rates = jnp.linspace(
        math.log(cfg.decay_fast), math.log(cfg.decay_slow), n_ch, dtype=jnp.float32
    )
    return {
        "ffn": layers,
        "decay_log_rate": Ax(log_rates, ("hyena_channels",)),
        "window_bias": Ax(jnp.zeros((n_ch,), jnp.float32), ("hyena_channels",)),
        # per-(order,channel) residual skip gain (the "D" term in SSM view)
        "skip": Ax(jnp.ones((n_ch,), jnp.float32), ("hyena_channels",)),
    }


def evaluate_filters(params: Dict[str, Any], cfg: FilterConfig, L: int) -> jax.Array:
    """h: (order, d_model, L) float32 — Algorithm 2 (parallel across N, L)."""
    z = positional_encoding(L, cfg.pos_dim)  # (L, De)
    h = z
    n_layers = len(params["ffn"])
    for i, layer in enumerate(params["ffn"]):
        h = h @ layer["w"] + layer["b"]
        if i < n_layers - 1:
            h = jnp.sin(cfg.sine_freq * h)
    # (L, order*d_model) -> exponential-decay window modulation
    t = jnp.arange(L, dtype=jnp.float32)[:, None] / max(L, 1)
    rate = jnp.exp(params["decay_log_rate"])[None, :]  # (1, C)
    window = jnp.exp(-rate * t * 8.0)
    window = window + jax.nn.sigmoid(params["window_bias"])[None, :] * 0.1
    h = h * window  # (L, C)
    if cfg.max_support:
        h = jnp.where(
            (jnp.arange(L) < cfg.max_support)[:, None], h, 0.0
        )
    h = h.reshape(L, cfg.order, cfg.d_model).transpose(1, 2, 0)  # (order, D, L)
    if cfg.normalized:
        # unit-l1 filters stabilize deep stacks (official repo option); keeps
        # |H(u)| bounded across orders.
        h = h / (jnp.sum(jnp.abs(h), axis=-1, keepdims=True) + 1e-8)
    return h


def filter_skip(params: Dict[str, Any], cfg: FilterConfig) -> jax.Array:
    """Per-(order, D) skip gain, shape (order, D)."""
    return params["skip"].reshape(cfg.order, cfg.d_model)
