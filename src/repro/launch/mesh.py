"""Production meshes. Defined as functions (never module-level constants)
so importing this module never touches jax device state.

Single pod: 16×16 = 256 chips (TPU v5e pod), axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries data parallelism across the inter-pod (DCN/ICI) links; batch
shards over ("pod", "data") via the 'data' alias in repro.distributed.ctx.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for in-process distributed tests (host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def parse_mesh_arg(spec: str):
    """``"DxM"`` CLI string -> debug mesh (shared by the serving example
    and the benchmarks, so the mesh-flag syntax lives in one place)."""
    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() and int(p) > 0 for p in parts):
        raise ValueError(
            f"mesh spec must be 'DxM' with positive ints (e.g. 2x4), "
            f"got {spec!r}"
        )
    return make_debug_mesh(int(parts[0]), int(parts[1]))
