import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# test hook: REPRO_DRYRUN_DEVICES overrides the placeholder-device count
# (still before any jax import — jax locks the device count on first init).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and capture the roofline inputs.

Per cell this produces artifacts/dryrun/<mesh>/<arch>__<shape>.json with:
  * compile proof: memory_analysis (bytes/device), compile wall-time,
  * cost_analysis of the full compiled step (NOTE: XLA counts while-loop
    bodies ONCE — verified empirically — so scanned layer stacks undercount;
    we therefore also compile depth-1 and depth-2 *unrolled* probes and
    extrapolate: total = overhead + n_groups × (d2 − d1)),
  * per-collective byte counts parsed from the partitioned HLO (same probe
    extrapolation), split by op kind,
  * MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) for the useful-compute ratio.

Cell policy (DESIGN.md §5): `long_500k` needs sub-quadratic attention —
mamba2/recurrentgemma run natively; pure full-attention archs run the cell
with the paper's drop-in swap (`--mixer hyena`, marked "hyena-swap").
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.common.param import split_params
from repro.configs import get_config
from repro.configs.registry import ASSIGNED
from repro.configs.shapes import SHAPES, token_specs
from repro.core.conv_api import resolve_conv_backend
from repro.distributed import ctx
from repro.distributed.execution import ExecutionContext
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.mixer_api import resolve_remat_policy
from repro.train import optim as O
from repro.train.trainer import TrainConfig, abstract_train_state, make_train_step

PAPER_ARCHS = ["hyena-153m", "hyena-1.3b"]  # the paper's own models, extra rows

# ---------------------------------------------------------------- HLO parse

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _type_bytes(typestr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by collectives (result-shape bytes, '-done'
    ops excluded by matching '-start'/plain forms only)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        typestr, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _type_bytes(typestr)
    return out


# ------------------------------------------------------------- param specs

def abstract_params(cfg, serve: bool = False):
    """(ShapeDtypeStruct tree, logical axes tree) without allocation."""
    captured = {}

    def build():
        vals, axes = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
        captured["axes"] = axes
        return vals

    vals = jax.eval_shape(build)
    if serve:  # serving holds bf16 weights
        vals = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if jnp.issubdtype(s.dtype, jnp.floating) else s,
            vals,
        )
    return vals, captured["axes"]


# Sharding decisions all come from the shared ExecutionContext (rule
# engine in repro.distributed.sharding; decode caches from the mixers'
# cache_shard_axes specs) — this module used to carry its own heuristic
# cache-sharding tree and hand-built optimizer-state shardings.


# ------------------------------------------------------------- cell runner

def model_flops_params(cfg, params_struct) -> Dict[str, float]:
    leaves = jax.tree_util.tree_flatten_with_path(params_struct)[0]
    total = 0
    expert = 0
    embed_like = 0
    for path, leaf in leaves:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        total += n
        if "moe" in keys and "router" not in keys:
            expert += n
        if "embed~table" in keys or keys.startswith("head"):
            embed_like += n
    active = total - expert
    if cfg.moe and cfg.n_experts:
        active += expert * cfg.top_k / cfg.n_experts
    return {"n_params": total, "n_active": active, "n_embed": embed_like}


def _reduced_depth_cfg(cfg, groups: int):
    plen = len(cfg.pattern)
    # keep the tail out of probes: body cost comes from (d2 - d1)
    return dataclasses.replace(cfg, n_layers=plen * groups)


def build_step(cfg, shape_name: str, mesh: Mesh, *, unroll=False, probe_groups=None):
    """Returns (fn, args, in_shardings, donate) ready for jit().lower()."""
    shape = SHAPES[shape_name]
    run_cfg = cfg if probe_groups is None else _reduced_depth_cfg(cfg, probe_groups)
    if shape.kind == "train":
        # conv backend resolved once, against the registry: explicit override
        # > $REPRO_CONV_BACKEND > default — unknown names raise here with the
        # registered list, not mid-lowering.
        tcfg = TrainConfig(
            optimizer=O.AdamWConfig(), remat=True, unroll=unroll,
            conv_backend=resolve_conv_backend(),
            remat_policy=resolve_remat_policy(),
            grad_compression=os.environ.get("REPRO_GRAD_COMPRESSION") or None,
        )
        ectx = tcfg.apply_context(mesh=mesh)
        # the trainer's own state description (incl. compression residuals
        # when enabled) — no hand-built {"m","v","step"} mirror here
        state, axes = abstract_train_state(run_cfg, tcfg)
        state_shard = ectx.train_state_shardings(axes, state)
        specs = token_specs(run_cfg, shape)
        batch = {k: v for k, v in specs.items()}
        batch_shard = {
            k: ectx.data_sharding(v.ndim, v.shape[0])
            for k, v in batch.items()
        }
        step = make_train_step(run_cfg, tcfg)
        return step, (state, batch), (state_shard, batch_shard), (0,)
    if shape.kind == "prefill":
        fwd_ctx = ExecutionContext(
            conv_backend=resolve_conv_backend(), unroll=unroll,
            mesh=mesh, fsdp=True,
        )
        params, axes = abstract_params(run_cfg, serve=True)
        pshard = fwd_ctx.param_shardings(axes, params)
        specs = token_specs(run_cfg, shape)
        batch_shard = {
            k: fwd_ctx.data_sharding(v.ndim, v.shape[0])
            for k, v in specs.items()
        }

        def fwd(params, batch):
            logits, _ = lm.forward(
                params, run_cfg, batch["tokens"],
                batch.get("frontend_embeds"), ctx=fwd_ctx,
            )
            return logits

        return fwd, (params, specs), (pshard, batch_shard), ()
    # decode
    serve_ctx = ExecutionContext(unroll=unroll, mesh=mesh, fsdp=True)
    params, axes = abstract_params(run_cfg, serve=True)
    pshard = serve_ctx.param_shardings(axes, params)
    dspecs = input_specs_decode(run_cfg, shape)
    # rule-driven decode-cache shardings from the mixers' cache_shard_axes
    # specs — the exact layout the mesh-native ServeEngine holds its pool in
    cshard = serve_ctx.cache_shardings(run_cfg, dspecs["caches"])
    tok_shard = serve_ctx.data_sharding(1, shape.batch)

    def serve_fn(params, token, caches):
        return lm.decode_step(params, run_cfg, token, caches, ctx=serve_ctx)

    return (
        serve_fn,
        (params, dspecs["token"], dspecs["caches"]),
        (pshard, tok_shard, cshard),
        (2,),
    )


def input_specs_decode(cfg, shape):
    caches = jax.eval_shape(
        lambda: lm.init_caches(cfg, shape.batch, shape.seq, dtype=jnp.bfloat16)
    )
    return {
        "token": jax.ShapeDtypeStruct((shape.batch,), jnp.int32),
        "caches": caches,
    }


def compile_cell(cfg, shape_name: str, mesh: Mesh, *, unroll=False,
                 probe_groups=None, want_text=True) -> Dict[str, Any]:
    fn, args, shardings, donate = build_step(
        cfg, shape_name, mesh, unroll=unroll, probe_groups=probe_groups
    )
    t0 = time.time()
    with ctx.use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # jax<0.5 returns a one-dict list per device
        cost = cost[0] if cost else {}
    out = {
        "compile_s": round(dt, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
    }
    if want_text:
        out["collectives"] = collective_bytes(compiled.as_text())
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probes: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    if os.environ.get("REPRO_CAPACITY_FACTOR"):
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(os.environ["REPRO_CAPACITY_FACTOR"])
        )
    shape = SHAPES[shape_name]
    swapped = False
    if shape_name == "long_500k" and not cfg.subquadratic:
        cfg = cfg.with_mixer("hyena")  # the paper's drop-in replacement
        swapped = True
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    plen = len(cfg.pattern)
    n_groups = cfg.n_layers // plen
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.shape.values()),
        "chips": n_chips,
        "hyena_swap": swapped,
        "pattern": list(cfg.pattern),
        "n_layers": cfg.n_layers,
        "status": "ok",
        "conv_backend": resolve_conv_backend(),
        "remat_policy": resolve_remat_policy(),
    }
    params_struct, _ = abstract_params(cfg)
    record.update(model_flops_params(cfg, params_struct))
    # tokens processed by this step (for MODEL_FLOPS = 6·N·D)
    if shape.kind == "train":
        record["tokens_per_step"] = shape.batch * shape.seq
    elif shape.kind == "prefill":
        record["tokens_per_step"] = shape.batch * shape.seq
    else:
        record["tokens_per_step"] = shape.batch
    # 6ND counts fwd+bwd (train); fwd-only steps are 2ND
    nd_factor = 6.0 if shape.kind == "train" else 2.0
    record["model_flops"] = nd_factor * record["n_active"] * record["tokens_per_step"]

    record["full"] = compile_cell(cfg, shape_name, mesh, want_text=True)
    if probes and n_groups >= 2:
        d1 = compile_cell(cfg, shape_name, mesh, unroll=True, probe_groups=1)
        d2 = compile_cell(cfg, shape_name, mesh, unroll=True, probe_groups=2)
        record["probe_d1"] = d1
        record["probe_d2"] = d2

        def extrap(f1, f2):
            if f1 is None or f2 is None:
                return None
            body = f2 - f1
            return f1 + (n_groups - 1) * body

        record["extrapolated"] = {
            "flops": extrap(d1["cost_analysis"]["flops"],
                            d2["cost_analysis"]["flops"]),
            "bytes_accessed": extrap(d1["cost_analysis"]["bytes_accessed"],
                                     d2["cost_analysis"]["bytes_accessed"]),
            "collectives": {
                k: extrap(d1["collectives"].get(k, 0), d2["collectives"].get(k, 0))
                for k in set(d1["collectives"]) | set(d2["collectives"])
            },
        }
    elif probes:
        record["extrapolated"] = {
            "flops": record["full"]["cost_analysis"]["flops"],
            "bytes_accessed": record["full"]["cost_analysis"]["bytes_accessed"],
            "collectives": record["full"].get("collectives", {}),
        }
    return record


def cells_for(archs, shapes):
    for a in archs:
        for s in shapes:
            yield a, s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--paper", action="store_true", help="also run paper archs")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    if args.paper and not args.arch:
        archs += PAPER_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for multi in meshes:
        mesh_tag = "pod2x16x16" if multi else "pod16x16"
        outdir = os.path.join(args.out, mesh_tag)
        os.makedirs(outdir, exist_ok=True)
        for arch, shape in cells_for(archs, shapes):
            path = os.path.join(outdir, f"{arch}__{shape}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {mesh_tag} {arch} {shape}")
                continue
            print(f"[run ] {mesh_tag} {arch} {shape}", flush=True)
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, multi, probes=not args.no_probes)
            except Exception as e:  # record the failure, keep sweeping
                rec = {
                    "arch": arch, "shape": shape, "mesh": mesh_tag,
                    "status": "failed", "error": str(e)[-2000:],
                    "traceback": traceback.format_exc()[-4000:],
                }
            rec["wall_s"] = round(time.time() - t0, 1)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[done] {mesh_tag} {arch} {shape} -> {rec['status']} "
                  f"({rec['wall_s']}s)", flush=True)
            jax.clear_caches()  # keep host RAM flat across the 96-cell sweep


if __name__ == "__main__":
    main()
