"""Sequence-parallel causal FFT convolution (context parallelism for Hyena).

For 500K-token contexts the (B, L, D) activations cannot hold L on one chip.
We decompose the length-N FFT (N = 2L zero-padded) Cooley–Tukey style with
N = P · M over a P-way mesh axis:

    X[k₂ + M·k₁] = Σ_{n₁<P} W_N^{n₁(k₂ + M k₁)} [ Σ_{n₂<M} x[n₂P + n₁] W_M^{n₂k₂} ]

i.e. (1) each shard FFTs its local decimated subsequence (stride-P
decimation = all-to-all re-layout), (2) multiply twiddles, (3) a P-point
DFT *across* shards — a small dense matmul over the mesh axis implemented
with one all-to-all + local contraction.  Total comm: 2 all-to-alls of the
activation instead of an L-sized all-gather — P× less memory traffic.

Implemented with shard_map over one mesh axis; validated in tests against
the single-device fft_causal_conv on 8 host devices.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fftconv import fft_causal_conv


def _sp_conv_body(u_blk, h_blk, skip, *, axis: str, L: int, D: int):
    """shard_map body. u_blk: (B, L/P, D) contiguous block of the sequence;
    h_blk: (D, L/P) block of taps.  Strategy: all-gather is avoided for the
    *output*; we compute Y = irfft(rfft(u)·rfft(h)) with the FFT distributed
    by re-layout:  contiguous blocks → decimated (stride-P) layout is an
    all-to-all; local FFTs of length N/P; cross-shard P-point DFT via
    ppermute-accumulated matmul (P is small: the mesh axis).
    """
    # jax.lax.axis_size is new-API only; psum(1) is the portable spelling
    P_sz = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    B = u_blk.shape[0]
    Lp = u_blk.shape[1]
    N = 2 * L  # zero-padded FFT length
    Mloc = N // P_sz  # local FFT length

    # ---- step 1: re-layout contiguous -> decimated via all_to_all.
    # Build the local contribution to every shard's decimated stream:
    # global index n = blk_start + j ; decimated stream r owns n ≡ r (mod P).
    # Pad the local block to its slice of the length-N stream first.
    blk_start = idx * Lp
    # local padded stream chunk: positions [idx*N/P, (idx+1)*N/P) of pad(u)
    # Our block is positions [idx*Lp, idx*Lp + Lp) of the *unpadded* u; the
    # zero pad occupies [L, 2L). Re-layout directly from (B, Lp, D):
    # decimated row r, slot m corresponds to n = m*P + r.
    m = jnp.arange(Mloc)
    # for each target shard r: which local j (if any) maps to (m, r)
    # n = m*P_sz + r ; local j = n - blk_start in [0, Lp)
    def gather_for_r(r):
        n = m * P_sz + r
        j = n - blk_start
        ok = (j >= 0) & (j < Lp) & (n < L)
        jc = jnp.clip(j, 0, Lp - 1)
        vals = u_blk[:, jc, :]  # (B, Mloc, D)
        return jnp.where(ok[None, :, None], vals, 0.0)

    per_r = jnp.stack([gather_for_r(r) for r in range(P_sz)], axis=0)
    # (P, B, Mloc, D): shard p's contribution to decimated stream r
    dec = jax.lax.psum_scatter(per_r, axis, scatter_dimension=0, tiled=False)
    # dec: (B, Mloc, D) — this shard now owns decimated stream r = idx

    # ---- step 2: local FFT of the decimated stream + twiddle
    Dec = jnp.fft.fft(dec.astype(jnp.complex64), axis=1)  # (B, Mloc, D), k2
    k2 = jnp.arange(Mloc)
    tw = jnp.exp(-2j * jnp.pi * (idx * k2) / N).astype(jnp.complex64)
    Dec = Dec * tw[None, :, None]

    # ---- step 3: P-point DFT across shards: X_k1[k2] =
    # Σ_r W_P^{r·k1} Dec_r[k2]; each shard ends owning spectrum block
    # k1 = idx.  This shard (owner of Dec_r, r = idx) sends its rotated
    # contribution to every k1 via one all_to_all, then sums locally.
    sendme = jnp.stack(
        [jnp.exp(-2j * jnp.pi * (idx * k1) / P_sz) * Dec for k1 in range(P_sz)],
        axis=0,
    )  # (P, B, Mloc, D) — block k1 for each destination
    recv = jax.lax.all_to_all(sendme, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    X = jnp.sum(recv, axis=0)  # (B, Mloc, D): spectrum block k1 = idx

    # ---- step 4: multiply by the filter spectrum block (computed the same
    # way for h — but h is small enough per-channel: gather taps fully).
    h_full = jax.lax.all_gather(h_blk, axis, axis=1, tiled=True)  # (D, L)
    H = jnp.fft.fft(
        jnp.pad(h_full.astype(jnp.float32), ((0, 0), (0, N - L))), axis=1
    ).astype(jnp.complex64)  # (D, N)
    kglob = idx * Mloc + jnp.arange(Mloc)
    Hblk = H[:, kglob].T  # (Mloc, D)
    Y = X * Hblk[None, :, :]

    # ---- step 5: inverse transform via conj-FFT: ifft(Y) =
    # conj(fft(conj(Y)))/N.  Input layout is contiguous spectrum blocks
    # (k = idx·M + k2), so use decimation-in-frequency:
    #   z[P·m + s] = Σ_{k2} W_M^{k2 m} [ W_N^{k2 s} Σ_{k1} c_{k1}[k2] W_P^{k1 s} ]
    # i.e. cross-shard P-point DFT FIRST, then twiddle, then local FFT.
    Yc = jnp.conj(Y)
    send2 = jnp.stack(
        [jnp.exp(-2j * jnp.pi * (idx * s) / P_sz) * Yc for s in range(P_sz)],
        axis=0,
    )  # our (k1 = idx) term of d_s, for every destination s
    recv2 = jax.lax.all_to_all(send2, axis, split_axis=0, concat_axis=0,
                               tiled=False)
    d = jnp.sum(recv2, axis=0)  # d_{s=idx}[k2]
    k2v = jnp.arange(Mloc)
    d = d * jnp.exp(-2j * jnp.pi * (k2v * idx) / N).astype(jnp.complex64)[None, :, None]
    zdec = jnp.fft.fft(d, axis=1)  # entries m: conj(y)[P·m + idx]·N
    y_time = jnp.conj(zdec) / N  # y at positions n ≡ idx (mod P) — re-layout
    # back to contiguous blocks with one more scatter.
    m2 = jnp.arange(Mloc)
    n_pos = m2 * P_sz + idx
    def slice_for_owner(o):
        lo = o * Lp
        ok = (n_pos >= lo) & (n_pos < lo + Lp) & (n_pos < L)
        return jnp.where(ok[None, :, None], y_time.real, 0.0), ok

    outs = []
    for o in range(P_sz):
        v, ok = slice_for_owner(o)
        # scatter into the owner's local (B, Lp, D) frame
        j = jnp.clip(n_pos - o * Lp, 0, Lp - 1)
        frame = jnp.zeros((u_blk.shape[0], Lp, u_blk.shape[2]), jnp.float32)
        frame = frame.at[:, j, :].add(jnp.where(ok[None, :, None], v, 0.0))
        outs.append(frame)
    sendback = jnp.stack(outs, axis=0)
    y_blk = jax.lax.psum_scatter(sendback, axis, scatter_dimension=0,
                                 tiled=False)
    if skip is not None:
        y_blk = y_blk + u_blk.astype(jnp.float32) * skip[None, None, :]
    return y_blk.astype(u_blk.dtype)


def sp_fft_causal_conv(
    u: jax.Array,  # (B, L, D), L sharded over `axis` in contiguous blocks
    h: jax.Array,  # (D, L), taps sharded over `axis` on the L dim
    skip: Optional[jax.Array],
    mesh: Mesh,
    axis: str = "model",
) -> jax.Array:
    """Distributed causal conv via two-stage Cooley–Tukey FFT; numerics
    validated against fft_causal_conv in tests (8 host devices)."""
    B, L, D = u.shape
    skip_in = skip if skip is not None else jnp.zeros((D,), jnp.float32)
    from repro.distributed.ctx import shard_map

    fn = shard_map(
        lambda ub, hb, s: _sp_conv_body(ub, hb, s, axis=axis, L=L, D=D),
        mesh=mesh,
        in_specs=(P(None, axis, None), P(None, axis), P(None)),
        out_specs=P(None, axis, None),
    )
    return fn(u, h, skip_in)
