"""Sequence-parallel causal FFT convolution (context parallelism for Hyena).

For 500K-token contexts the (B, L, D) activations cannot hold L on one chip.
We decompose the length-N FFT (N = 2L zero-padded) Cooley–Tukey style with
N = P · M over a P-way mesh axis:

    X[k₂ + M·k₁] = Σ_{n₁<P} W_N^{n₁(k₂ + M k₁)} [ Σ_{n₂<M} x[n₂P + n₁] W_M^{n₂k₂} ]

i.e. (1) each shard FFTs its local decimated subsequence (stride-P
decimation = all-to-all re-layout), (2) multiply twiddles, (3) a P-point
DFT *across* shards — a small dense matmul over the mesh axis implemented
with one all-to-all + local contraction.  Total comm: 2 all-to-alls of the
activation instead of an L-sized all-gather — P× less memory traffic.

The same machinery is **differentiable**: :func:`sp_fft_causal_conv`
carries a ``custom_vjp`` (DESIGN.md §12).  The transpose of a causal conv
is an *anticausal correlation* — ``du_t = Σ_{s≥t} h_{s-t} dy_s`` — which in
the frequency domain is multiplication by the **conjugated** filter
spectrum (time-reversed taps).  The backward pass therefore reuses the
identical two-all-to-all distributed FFT pipeline:

    du = IDFT( DFT(dy) · conj(H) )          (same comm footprint as fwd)
    dh = IDFT( Σ_b DFT(dy_b) · conj(DFT(u_b)) )   (taps grad, L-sharded)

with every spectrum/inverse built from :func:`_dist_spectrum` /
:func:`_dist_inverse` — the decomposed halves of the forward body.

Non-divisible lengths are padded to the next multiple of the axis size and
the output truncated: causality makes the truncation exact (outputs at
``t < L`` never see the zero tail, and the padded taps are zero).

The Hyena output gate is fused into the post-conv elementwise epilogue
inside the shard_map body (``supports_gate``), bit-identical to the
registry's unfused two-pass fallback.

Implemented with shard_map over one mesh axis (batch stays sharded over the
data/pod axes); validated in tests against the single-device
fft_causal_conv — values and ``jax.grad`` — on 8 host devices.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_env(axis: str):
    # jax.lax.axis_size is new-API only; psum(1) is the portable spelling
    P_sz = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    return P_sz, idx


# --------------------------------------------------- distributed transforms
#
# The forward body used to be one monolith; it is now three reusable
# pieces so the backward pass can compose the same collectives:
#
#   _dist_spectrum : contiguous time block -> spectrum block   (2 collectives)
#   _taps_spectrum : L-sharded taps -> full filter spectrum block
#   _dist_inverse  : spectrum block -> contiguous time block   (2 collectives)

def _dist_spectrum(x_blk: jax.Array, *, axis: str, L: int) -> jax.Array:
    """(B, L/P, D) contiguous block of a length-L signal (zero-padded to
    N = 2L) -> this shard's spectrum block (B, M, D) at frequencies
    ``k = idx·M + k2`` (M = N/P).

    Strategy: contiguous blocks → decimated (stride-P) layout is an
    all-to-all (spelled psum_scatter over a masked stack); local FFTs of
    length N/P; twiddle; cross-shard P-point DFT via one all_to_all +
    local sum.
    """
    P_sz, idx = _axis_env(axis)
    B, Lp, D = x_blk.shape
    N = 2 * L
    Mloc = N // P_sz  # local FFT length
    x_blk = x_blk.astype(jnp.float32)

    # ---- re-layout contiguous -> decimated.  Global index n = blk_start+j;
    # decimated stream r owns n ≡ r (mod P); zero pad occupies [L, 2L).
    blk_start = idx * Lp
    m = jnp.arange(Mloc)

    def gather_for_r(r):
        n = m * P_sz + r
        j = n - blk_start
        ok = (j >= 0) & (j < Lp) & (n < L)
        jc = jnp.clip(j, 0, Lp - 1)
        vals = x_blk[:, jc, :]  # (B, Mloc, D)
        return jnp.where(ok[None, :, None], vals, 0.0)

    per_r = jnp.stack([gather_for_r(r) for r in range(P_sz)], axis=0)
    # (P, B, Mloc, D): shard p's contribution to decimated stream r
    dec = jax.lax.psum_scatter(per_r, axis, scatter_dimension=0, tiled=False)
    # dec: (B, Mloc, D) — this shard now owns decimated stream r = idx

    # ---- local FFT of the decimated stream + twiddle
    Dec = jnp.fft.fft(dec.astype(jnp.complex64), axis=1)  # (B, Mloc, D), k2
    k2 = jnp.arange(Mloc)
    tw = jnp.exp(-2j * jnp.pi * (idx * k2) / N).astype(jnp.complex64)
    Dec = Dec * tw[None, :, None]

    # ---- P-point DFT across shards: X_k1[k2] = Σ_r W_P^{r·k1} Dec_r[k2];
    # this shard (owner of Dec_r, r = idx) sends its rotated contribution
    # to every k1 via one all_to_all, then sums locally.
    sendme = jnp.stack(
        [jnp.exp(-2j * jnp.pi * (idx * k1) / P_sz) * Dec for k1 in range(P_sz)],
        axis=0,
    )  # (P, B, Mloc, D) — block k1 for each destination
    recv = jax.lax.all_to_all(sendme, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    return jnp.sum(recv, axis=0)  # (B, Mloc, D): spectrum block k1 = idx


def _taps_spectrum(h_blk: jax.Array, *, axis: str, L: int) -> jax.Array:
    """(D, L/P) taps block -> filter spectrum block (M, D) at this shard's
    frequencies.  Taps are small per channel (D·L, no batch dim), so one
    all_gather is cheap relative to the activation all-to-alls."""
    P_sz, idx = _axis_env(axis)
    N = 2 * L
    Mloc = N // P_sz
    h_full = jax.lax.all_gather(h_blk, axis, axis=1, tiled=True)  # (D, L)
    H = jnp.fft.fft(
        jnp.pad(h_full.astype(jnp.float32), ((0, 0), (0, N - L))), axis=1
    ).astype(jnp.complex64)  # (D, N)
    kglob = idx * Mloc + jnp.arange(Mloc)
    return H[:, kglob].T  # (Mloc, D)


def _dist_inverse(spec_blk: jax.Array, *, axis: str, L: int, Lp: int) -> jax.Array:
    """Spectrum block (B, M, D) (k = idx·M + k2) -> contiguous real time
    block (B, Lp, D) in fp32, truncated to the first L global positions.

    ifft via conj-FFT: ifft(Y) = conj(fft(conj(Y)))/N.  Input layout is
    contiguous spectrum blocks, so decimation-in-frequency: cross-shard
    P-point DFT FIRST, then twiddle, then local FFT, then one relayout
    back to contiguous time blocks.
    """
    P_sz, idx = _axis_env(axis)
    B, Mloc, D = spec_blk.shape
    N = 2 * L
    Yc = jnp.conj(spec_blk)
    send2 = jnp.stack(
        [jnp.exp(-2j * jnp.pi * (idx * s) / P_sz) * Yc for s in range(P_sz)],
        axis=0,
    )  # our (k1 = idx) term of d_s, for every destination s
    recv2 = jax.lax.all_to_all(send2, axis, split_axis=0, concat_axis=0,
                               tiled=False)
    d = jnp.sum(recv2, axis=0)  # d_{s=idx}[k2]
    k2v = jnp.arange(Mloc)
    d = d * jnp.exp(-2j * jnp.pi * (k2v * idx) / N).astype(jnp.complex64)[None, :, None]
    zdec = jnp.fft.fft(d, axis=1)  # entries m: conj(y)[P·m + idx]·N
    y_time = jnp.conj(zdec).real / N  # y at positions n ≡ idx (mod P)
    # re-layout decimated -> contiguous blocks with one more scatter.
    m2 = jnp.arange(Mloc)
    n_pos = m2 * P_sz + idx
    outs = []
    for o in range(P_sz):
        lo = o * Lp
        ok = (n_pos >= lo) & (n_pos < lo + Lp) & (n_pos < L)
        j = jnp.clip(n_pos - lo, 0, Lp - 1)
        frame = jnp.zeros((B, Lp, D), jnp.float32)
        frame = frame.at[:, j, :].add(jnp.where(ok[None, :, None], y_time, 0.0))
        outs.append(frame)
    sendback = jnp.stack(outs, axis=0)
    return jax.lax.psum_scatter(sendback, axis, scatter_dimension=0,
                                tiled=False)


# ------------------------------------------------------------------ bodies

def _fwd_body(u_blk, h_blk, skip, gate_blk, *, axis: str, L: int,
              want_core: bool):
    """shard_map forward body.  u_blk (B, L/P, D); h_blk (D, L/P); skip
    (D,)|None replicated; gate_blk (B, L/P, D)|None.  The gate+skip
    epilogue mirrors the registry's unfused fallback expression exactly —
    ``(gate * core.astype(gate.dtype)).astype(u.dtype)`` — so fusing it is
    bit-identical (DESIGN.md §7)."""
    B, Lp, D = u_blk.shape
    X = _dist_spectrum(u_blk, axis=axis, L=L)
    Hblk = _taps_spectrum(h_blk, axis=axis, L=L)
    y = _dist_inverse(X * Hblk[None], axis=axis, L=L, Lp=Lp)
    if skip is not None:
        y = y + u_blk.astype(jnp.float32) * skip[None, None, :]
    core = y.astype(u_blk.dtype)
    if gate_blk is None:
        return core
    out = (gate_blk * core.astype(gate_blk.dtype)).astype(u_blk.dtype)
    return (out, core) if want_core else out


def _bwd_body(dy_blk, u_blk, h_blk, skip, gate_blk, core_blk, *,
              axis: str, L: int, data_axes):
    """shard_map backward body — the conv transpose on the same collectives.

    With y = gate ⊙ (conv(u, h) + skip·u):
      dgate = dy ⊙ core                                  (local elementwise)
      dy_g  = dy ⊙ gate                                  (local elementwise)
      du    = corr(dy_g, h) + skip·dy_g  = IDFT(DFT(dy_g)·conj(H))
      dh    = Σ_b corr(dy_g, u)          = IDFT(Σ_b DFT(dy_g)·conj(DFT(u)))
      dskip = Σ_{b,t} dy_g ⊙ u                           (psum over axes)
    Correlations are exact on the N = 2L grid: positive lags [0, L) never
    wrap (supports < L), matching the truncated forward's adjoint exactly.
    """
    B, Lp, D = dy_blk.shape
    dy = dy_blk.astype(jnp.float32)
    dgate = None
    if gate_blk is not None:
        dgate = (dy * core_blk.astype(jnp.float32)).astype(gate_blk.dtype)
        dy = dy * gate_blk.astype(jnp.float32)
    dS = _dist_spectrum(dy, axis=axis, L=L)
    Hblk = _taps_spectrum(h_blk, axis=axis, L=L)
    du = _dist_inverse(dS * jnp.conj(Hblk)[None], axis=axis, L=L, Lp=Lp)
    dskip = None
    if skip is not None:
        du = du + dy * skip[None, None, :].astype(jnp.float32)
        # global sum over batch and time: local reduce + psum over the cp
        # axis (time shards) and the data axes (batch shards)
        dskip = jax.lax.psum(
            jnp.sum(dy * u_blk.astype(jnp.float32), axis=(0, 1)),
            (axis,) + tuple(data_axes),
        )
    U = _dist_spectrum(u_blk, axis=axis, L=L)
    dh_spec = jnp.sum(dS * jnp.conj(U), axis=0, keepdims=True)  # (1, M, D)
    dh = _dist_inverse(dh_spec, axis=axis, L=L, Lp=Lp)[0].T  # (D, Lp)
    if data_axes:  # batch rows live on the data shards: sum their taps grads
        dh = jax.lax.psum(dh, tuple(data_axes))
    return (
        du.astype(u_blk.dtype),
        dh.astype(h_blk.dtype),
        dskip,
        dgate,
    )


# ----------------------------------------------------------- shard_map glue

def _batch_specs(mesh: Mesh, axis: str, B: int):
    """Batch dim stays sharded over the data/pod axes when divisible (the
    training layout); otherwise replicated (the original prefill layout)."""
    data_axes = tuple(
        a for a in ("pod", "data") if a in mesh.shape and a != axis
    )
    data_sz = 1
    for a in data_axes:
        data_sz *= mesh.shape[a]
    if not data_axes or data_sz <= 1 or B % data_sz:
        return None, ()
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]
    return bspec, data_axes


def _run_fwd(mesh, axis, u, h, skip, gate, want_core):
    from repro.distributed.ctx import shard_map

    B, L, D = u.shape
    bspec, _ = _batch_specs(mesh, axis, B)
    act = P(bspec, axis, None)
    args = [u, h]
    specs = [act, P(None, axis)]
    if skip is not None:
        args.append(skip)
        specs.append(P(None))
    if gate is not None:
        args.append(gate)
        specs.append(act)
    has_skip, has_gate = skip is not None, gate is not None
    out_specs = (act, act) if (want_core and has_gate) else act

    def body(*xs):
        ub, hb = xs[0], xs[1]
        i = 2
        sb = gb = None
        if has_skip:
            sb = xs[i]
            i += 1
        if has_gate:
            gb = xs[i]
        return _fwd_body(ub, hb, sb, gb, axis=axis, L=L, want_core=want_core)

    fn = shard_map(
        body, mesh=mesh, in_specs=tuple(specs), out_specs=out_specs,
        check=False,  # complex FFT + multi-axis specs trip the vma checker
    )
    return fn(*args)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sp_conv(mesh: Mesh, axis: str, u, h, skip, gate):
    """Differentiable core (L divisible by the axis size).  The custom_vjp
    exists because (a) jax cannot transpose the FFT custom-call under
    shard_map on every backend/version, and (b) the hand-written adjoint
    keeps the backward comm footprint identical to forward instead of
    whatever the transpose of psum_scatter-of-masked-stacks lowers to."""
    return _run_fwd(mesh, axis, u, h, skip, gate, want_core=False)


def _sp_conv_fwd(mesh, axis, u, h, skip, gate):
    if gate is None:
        out = _run_fwd(mesh, axis, u, h, skip, gate, want_core=False)
        core = None
    else:
        out, core = _run_fwd(mesh, axis, u, h, skip, gate, want_core=True)
    return out, (u, h, skip, gate, core)


def _sp_conv_bwd(mesh, axis, res, dy):
    from repro.distributed.ctx import shard_map

    u, h, skip, gate, core = res
    B, L, D = u.shape
    bspec, data_axes = _batch_specs(mesh, axis, B)
    act = P(bspec, axis, None)
    has_skip, has_gate = skip is not None, gate is not None

    args = [dy, u, h]
    specs = [act, act, P(None, axis)]
    if has_skip:
        args.append(skip)
        specs.append(P(None))
    if has_gate:
        args.extend([gate, core])
        specs.extend([act, act])

    out_specs = [act, P(None, axis)]
    if has_skip:
        out_specs.append(P(None))
    if has_gate:
        out_specs.append(act)

    def body(*xs):
        dyb, ub, hb = xs[0], xs[1], xs[2]
        i = 3
        sb = gb = cb = None
        if has_skip:
            sb = xs[i]
            i += 1
        if has_gate:
            gb, cb = xs[i], xs[i + 1]
        du, dh, dskip, dgate = _bwd_body(
            dyb, ub, hb, sb, gb, cb, axis=axis, L=L, data_axes=data_axes
        )
        outs = [du, dh]
        if has_skip:
            outs.append(dskip)
        if has_gate:
            outs.append(dgate)
        return tuple(outs)

    fn = shard_map(
        body, mesh=mesh, in_specs=tuple(specs), out_specs=tuple(out_specs),
        check=False,
    )
    outs = list(fn(*args))
    du, dh = outs[0], outs[1]
    i = 2
    dskip = dgate = None
    if has_skip:
        dskip = outs[i]
        i += 1
    if has_gate:
        dgate = outs[i]
    return du, dh, dskip, dgate


_sp_conv.defvjp(_sp_conv_fwd, _sp_conv_bwd)


# ------------------------------------------------------------------ public

def sp_fft_causal_conv(
    u: jax.Array,  # (B, L, D), L sharded over `axis` in contiguous blocks
    h: jax.Array,  # (D, L), taps sharded over `axis` on the L dim
    skip: Optional[jax.Array],
    mesh: Mesh,
    axis: str = "model",
    gate: Optional[jax.Array] = None,  # (B, L, D) fused output gate
) -> jax.Array:
    """Distributed causal conv via two-stage Cooley–Tukey FFT, with a
    custom VJP so ``jax.grad`` reuses the same collectives (anticausal
    correlation = conjugated filter spectrum).

    ``L`` need not divide the axis size: inputs/taps are zero-padded to the
    next multiple and the output truncated — exact, because causal outputs
    at ``t < L`` never see the zero tail (this replaces the old silent
    full-``L`` single-device fallback, which was the OOM this backend
    exists to prevent).  Numerics and grads are validated against
    fft_causal_conv in tests (8 host devices).
    """
    B, L, D = u.shape
    # Pin the taps replicated BEFORE they cross the shard_map boundary.
    # When h is produced inside the same jit (the implicit-filter FFN),
    # GSPMD propagates the manual region's P(None, axis) layout back into
    # the producer and reshards it via "involuntary full rematerialization"
    # — which, on the filter net's transpose/reshape/iota graph, computes
    # *wrong values* (observed 0.5 abs error on |h|~0.36 taps, XLA CPU
    # SPMD; pinning to P(None, axis) still goes through the broken reshard,
    # only full replication sidesteps it).  The taps are (D, L) and
    # batch-independent, so replicating them is what the eager path always
    # did; the shard_map in_spec then splits a *correct* replicated tensor.
    h = jax.lax.with_sharding_constraint(h, NamedSharding(mesh, P()))
    P_sz = mesh.shape[axis]
    pad = (-L) % P_sz
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        h = jnp.pad(h, ((0, 0), (0, pad)))
        if gate is not None:
            gate = jnp.pad(gate, ((0, 0), (0, pad), (0, 0)))
    out = _sp_conv(mesh, axis, u, h, skip, gate)
    return out[:, :L] if pad else out
