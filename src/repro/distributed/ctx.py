"""Mesh context: model code calls ``shard(x, *axes)`` for activation
sharding constraints; with no active mesh (smoke tests, single device) the
call is the identity, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def current_cp_axis() -> Optional[str]:
    """Mesh axis the *sequence* dim is sharded over for context-parallel
    training, or None.  Set by ``ExecutionContext.scope()`` so registry
    backends (whose ``fn(u, h, skip, gate)`` signature carries no context)
    can resolve which axis their collectives run over."""
    return getattr(_state, "cp_axis", None)


@contextlib.contextmanager
def use_cp_axis(axis: Optional[str]):
    prev = current_cp_axis()
    _state.cp_axis = axis
    try:
        yield axis
    finally:
        _state.cp_axis = prev


def _expand_alias(name: str, mesh: Mesh):
    """'data' is an alias for all data-parallel axes — on the multi-pod mesh
    that's ('pod', 'data') so batch shards over pods too."""
    if name == "data" and "pod" in mesh.shape:
        return ("pod", "data")
    return (name,)


def _filter_spec(mesh: Mesh, shape, axes: Sequence) -> P:
    """Drop constraint entries that don't divide the dim (keeps model code
    mesh-shape agnostic: 40 heads over a 16-way axis degrades to replicated
    instead of failing)."""
    out = []
    used = set()
    for dim, ax in zip(shape, axes):
        if ax is None:
            out.append(None)
            continue
        names = (ax,) if isinstance(ax, str) else tuple(ax)
        names = sum((_expand_alias(n, mesh) for n in names), ())
        names = tuple(n for n in names if n in mesh.shape and n not in used)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if names and size > 0 and dim % size == 0:
            out.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            out.append(None)
    return P(*out)


def shard(x: jax.Array, *axes) -> jax.Array:
    """Sharding constraint by mesh-axis names (None = replicated dim).

    ``shard(x, "data", None, "model")``; a tuple entry shards one dim over
    several axes: ``shard(cache, None, ("data", "model"), None)``.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard: {len(axes)} axes for ndim {x.ndim}")
    spec = _filter_spec(mesh, x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """Version-compat ``shard_map``: ``jax.shard_map`` (new API, ``check_vma``)
    when present, else ``jax.experimental.shard_map`` (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )
