"""int8 error-feedback gradient compression for cross-pod data parallelism.

Cross-pod links are the scarcest bandwidth at 512+ chips.  Gradients are
quantized to int8 with a per-tensor scale before the pod-axis all-reduce
(8× fewer bytes on the slow links), de-quantized after, and the
quantization residual is fed back into the next step's gradient (error
feedback — keeps SGD/Adam convergence; Karimireddy et al. 2019).

``compressed_psum`` runs inside shard_map (true int8 wire traffic);
``apply`` is the stateful wrapper the trainer's jitted step uses when
``TrainConfig.grad_compression="int8_ef"`` (residual state lives in the
train state under ``"cgrad"``, so it checkpoints/reshards like everything
else — DESIGN.md §10).  The two forms share the scale (per-tensor global
amax, pmax-agreed in ``compressed_psum``) but not the rounding point: the
jit-SPMD step rounds the globally-reduced gradient once (≤ scale/2 error
per element), while the wire collective rounds each of P shards' partials
before summing (≤ P·scale/2 worst case).  The jit form is therefore the
*tighter* end of the channel — error feedback carries either residual into
the next step, but convergence results obtained with it bound the wire
form only up to that factor.  The byte saving on the DCN links needs the
shard_map form, which the pod-axis test lowers.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """Quantize → all-reduce(int32 accumulate) → dequantize.  The scale is
    itself max-reduced so all shards agree; accumulation in int32 avoids
    overflow up to 2^23 summands."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale


def init_residuals(grads) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


def compress_decompress_with_feedback(
    grads, residuals
) -> Tuple[Any, Any, Any]:
    """Single-process form (quantize+dequantize locally): returns
    (compressed-then-restored grads, new residuals, diagnostics).  The
    all-reduce itself is the mesh's job; this models the lossy channel and
    carries the error-feedback state."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq, g32 - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    err = jnp.stack([jnp.mean(jnp.abs(o[1])) for o in outs]).mean()
    return new_g, new_r, {"compression_abs_err": err}


# The name the trainer (and its docstring) use: error-feedback int8
# compression of the gradient tree inside the jitted train step.
apply = compress_decompress_with_feedback
