"""State sharding rules: logical axis names → mesh axes.

The rule engine maps trees of *logical axis annotations* to NamedShardings:

  - priority lists per logical name (first candidate that divides wins),
  - no mesh axis reused twice within one tensor's spec,
  - FSDP: "embed"-family weight dims shard over the data axes when enabled
    (ZeRO-3 — required to fit 72B/132B optimizer states on 256 chips).

It is not params-only: :func:`tree_shardings` walks an arbitrary state tree
(a *partial* axes tree replicates everything it does not name), and two
derived entry points cover the production state shapes —
:func:`train_state_shardings` for ``{"params", "opt": {m, v, step}}`` and
``repro.models.lm.cache_shardings`` for decode-cache pools (logical names
come from each mixer's ``cache_shard_axes`` spec; DESIGN.md §9).  All of it
is reached through ``ExecutionContext`` (repro.distributed.execution) so
sharding decisions live in exactly one place.

Activation sharding is *not* rule-driven — step functions place explicit
``ctx.shard`` constraints (DESIGN.md §6).  That convention is what lets the
reversible substrate (DESIGN.md §15) work here unchanged: its dual-stream
scan carry ``(x1, x2)`` is an activation, pinned to the residual-stream
layout (Megatron-SP ``model`` or ``cp_axis`` over the sequence dim) by the
coupling itself on both streams, while the stacked per-group parameter
trees it scans over are byte-identical to the standard path's — the same
``train_state_shardings`` output applies whichever way the flag is set.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> candidate mesh-axis groups, tried in order.
# An entry is a tuple of mesh axes meaning "shard this dim over the product".
TP_RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    "mlp": [("model",)],
    "attn_hidden": [("model",)],
    "kv_hidden": [("model",)],
    "vocab": [("model",)],
    "experts": [("model",)],
    "expert_ff": [("model",)],
    "hyena_inner": [("model",)],
    "hyena_out": [("model",)],
    "hyena_channels": [("model",)],
    "rnn_hidden": [("model",)],
    "ssd_inner": [("model",)],
    "ssd_state": [],
    "heads": [("model",)],
    "embed": [],  # replicated unless fsdp
    # decode-cache logical names (mixer ``cache_shard_axes`` specs),
    # resolved through the same engine so the serving cache shards exactly
    # like the weights that produce it.  Slot/batch dims take the data
    # axes (each data-parallel group owns a subset of requests — the
    # layout big-batch decode cells need to fit HBM); head/channel dims
    # reuse the TP rules above; per-slot cursors carry NO spec at all and
    # therefore replicate — every chip needs every slot's position for
    # RoPE/validity masks.
    "cache_slots": [("pod", "data")],
    # long sequence dims (KV rings, hyena operand histories): fallback
    # shard over whatever axes the preferred dims left (see
    # RULE_PRIORITY) — data+model for a batch-1 500K ring, model when the
    # batch took data, nothing when heads/channels already cover model
    # and slots cover data.  Contracting a time-sharded cache costs a
    # psum, so it never outranks head/channel sharding; it exists so a
    # 500K-token cache degrades to sharded-but-slower instead of
    # replicated-and-OOM (e.g. 8 KV heads on a 16-way model axis).
    "kv_seq": [("pod", "data", "model"), ("model",), ("pod", "data")],
}
# cross-dim assignment order within one tensor: lower value is assigned
# first (first-divides-wins remains the tie-break at equal priority, in
# dim order).  Unlisted names default to 0, so parameter resolution is
# unchanged; "kv_seq" only picks up mesh axes the preferred dims left.
RULE_PRIORITY: Dict[str, int] = {"kv_seq": 1}
FSDP_EMBED = ["embed"]  # logical names that take the data axes under fsdp


def resolve_spec(
    axes: Optional[Tuple[Optional[str], ...]],
    shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    fsdp: bool = False,
    data_axes: Tuple[str, ...] = ("data",),
    extra_leading: int = 0,
    extra_rules: Optional[Dict[str, Sequence[Tuple[str, ...]]]] = None,
) -> P:
    """PartitionSpec for one parameter. ``extra_leading`` accounts for
    stacked-layer leading dims added by scan-style init (replicated).
    ``extra_rules`` overlays caller-scoped logical names (e.g. the
    context-parallel ``cp_seq`` rule, whose mesh axis is an
    ``ExecutionContext`` knob rather than a global)."""
    if axes is None:
        return P()
    rules = dict(TP_RULES)
    if extra_rules:
        rules.update(extra_rules)
    if fsdp:
        for name in FSDP_EMBED:
            rules[name] = [tuple(a for a in data_axes if a in mesh.shape)]
    entries = [None] * extra_leading + list(axes)
    shape = tuple(shape)
    out = [None] * len(entries)
    used: set = set()
    # assignment order: RULE_PRIORITY first (so e.g. a "heads" dim claims
    # the model axis before the "kv_seq" fallback), dim position second
    order = sorted(
        range(min(len(shape), len(entries))),
        key=lambda i: (RULE_PRIORITY.get(entries[i], 0), i),
    )
    for i in order:
        dim, name = shape[i], entries[i]
        choice = None
        for cand in rules.get(name, []) if name else []:
            cand = tuple(a for a in cand if a in mesh.shape and a not in used)
            if not cand:
                continue
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                choice = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out[i] = choice
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(
    axes_tree: Any,
    values_tree: Any,
    mesh: Mesh,
    *,
    fsdp: bool = False,
    data_axes: Tuple[str, ...] = ("data",),
) -> Any:
    """Tree of NamedShardings parallel to the params tree.

    Handles scan-stacked parameters: if a value has more dims than its
    annotation, leading dims are treated as replicated stack dims.
    """

    def one(ax, val):
        extra = val.ndim - (len(ax) if ax is not None else 0)
        spec = resolve_spec(
            ax, val.shape, mesh, fsdp=fsdp, data_axes=data_axes,
            extra_leading=max(extra, 0),
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        one, axes_tree, values_tree, is_leaf=_is_axes_leaf
    )


# ------------------------------------------------- arbitrary state trees
#
# ``param_shardings`` requires a fully parallel axes tree.  Real state trees
# (train state, decode-cache pools) are only *partially* annotated: scalars,
# cursors, and bookkeeping leaves carry no logical axes.  ``tree_shardings``
# walks both trees together and replicates everything the axes tree does not
# name — one rule engine for params, optimizer moments, and serving caches.

def _is_axes_leaf(a) -> bool:
    return a is None or (
        isinstance(a, tuple) and all(x is None or isinstance(x, str) for x in a)
    )


def batch_spec(
    mesh: Mesh,
    ndim: int,
    dim0: int,
    seq_len: Optional[int] = None,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    cp_axis: Optional[str] = None,
) -> P:
    """PartitionSpec for one *input* leaf, through the same rule engine as
    state trees: dim 0 resolves the ``batch`` rule (all data axes, then the
    non-pod subset, first-divides-wins), and — under context parallelism —
    dim 1 resolves ``cp_seq`` against the context's cp axis.  Non-divisible
    dims degrade to replicated, never error."""
    full = tuple(a for a in ("pod", *data_axes) if a in mesh.shape)
    slim = tuple(a for a in data_axes if a in mesh.shape)
    rules: Dict[str, Sequence[Tuple[str, ...]]] = {
        "batch": [c for c in (full, slim) if c],
        "cp_seq": [(cp_axis,)] if cp_axis and cp_axis in mesh.shape else [],
    }
    axes: list = ["batch"] + [None] * (ndim - 1)
    shape: list = [dim0] + [0] * (ndim - 1)
    if cp_axis is not None and ndim >= 2 and seq_len is not None:
        axes[1] = "cp_seq"
        shape[1] = seq_len
    return resolve_spec(
        tuple(axes), tuple(shape), mesh, data_axes=data_axes,
        extra_rules=rules,
    )


def tree_shardings(
    axes_tree: Any,
    values_tree: Any,
    mesh: Mesh,
    *,
    fsdp: bool = False,
    data_axes: Tuple[str, ...] = ("data",),
    extra_rules: Optional[Dict[str, Sequence[Tuple[str, ...]]]] = None,
) -> Any:
    """NamedShardings for an arbitrary state tree.

    ``axes_tree`` is a *partial* mirror of ``values_tree``: where it holds a
    logical-axes annotation the rules apply (with replicated leading stack
    dims, as in :func:`param_shardings`); where it holds ``None`` — or stops
    short of a whole subtree — every leaf below is replicated.  Dict nodes
    recurse by key; missing keys replicate.
    """
    repl = NamedSharding(mesh, P())

    def walk(ax, val):
        if _is_axes_leaf(ax):
            if ax is None or not hasattr(val, "ndim"):
                # no annotation — or an annotation pointing at a subtree
                # (structure mismatch): replicate everything below
                return jax.tree_util.tree_map(lambda _: repl, val)
            extra = val.ndim - len(ax)
            spec = resolve_spec(
                ax, val.shape, mesh, fsdp=fsdp, data_axes=data_axes,
                extra_leading=max(extra, 0), extra_rules=extra_rules,
            )
            return NamedSharding(mesh, spec)
        if isinstance(ax, dict) and isinstance(val, dict):
            return {
                k: (walk(ax[k], v) if k in ax
                    else jax.tree_util.tree_map(lambda _: repl, v))
                for k, v in val.items()
            }
        if isinstance(ax, (list, tuple)) and isinstance(val, (list, tuple)):
            out = [walk(a, v) for a, v in zip(ax, val)]
            out += [
                jax.tree_util.tree_map(lambda _: repl, v)
                for v in val[len(ax):]
            ]
            return type(val)(out) if isinstance(val, tuple) else out
        # structure mismatch (e.g. annotated subtree vs bare leaf): replicate
        return jax.tree_util.tree_map(lambda _: repl, val)

    return walk(axes_tree, values_tree)


def train_state_shardings(
    param_axes: Any,
    state: Any,
    mesh: Mesh,
    *,
    fsdp: bool = False,
    data_axes: Tuple[str, ...] = ("data",),
) -> Any:
    """Shardings for the canonical train state ``{"params", "opt"}`` plus
    optional per-parameter companion trees (``"cgrad"`` — the int8
    error-feedback compression residuals).

    Adam moments — and the compression residuals — mirror the parameter
    layout (they are elementwise functions of the grads — co-locating them
    is what makes FSDP/ZeRO-3 fit); every other opt leaf (step counters
    etc.) replicates.
    """
    axes = {
        "params": param_axes,
        "opt": {
            k: (param_axes if k in ("m", "v") else None)
            for k in state.get("opt", {})
        },
    }
    if "cgrad" in state:
        axes["cgrad"] = param_axes
    return tree_shardings(
        axes, state, mesh, fsdp=fsdp, data_axes=data_axes
    )
