"""Parameter sharding rules: logical axis names → mesh axes.

Every ``init_*`` returns Ax-annotated params; ``param_shardings`` maps the
logical-axes tree to NamedShardings with:

  - priority lists per logical name (first candidate that divides wins),
  - no mesh axis reused twice within one tensor's spec,
  - FSDP: "embed"-family weight dims shard over the data axes when enabled
    (ZeRO-3 — required to fit 72B/132B optimizer states on 256 chips).

Activation sharding is *not* rule-driven — step functions place explicit
``ctx.shard`` constraints (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> candidate mesh-axis groups, tried in order.
# An entry is a tuple of mesh axes meaning "shard this dim over the product".
TP_RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    "mlp": [("model",)],
    "attn_hidden": [("model",)],
    "kv_hidden": [("model",)],
    "vocab": [("model",)],
    "experts": [("model",)],
    "expert_ff": [("model",)],
    "hyena_inner": [("model",)],
    "hyena_out": [("model",)],
    "hyena_channels": [("model",)],
    "rnn_hidden": [("model",)],
    "ssd_inner": [("model",)],
    "ssd_state": [],
    "heads": [("model",)],
    "embed": [],  # replicated unless fsdp
}
FSDP_EMBED = ["embed"]  # logical names that take the data axes under fsdp


def resolve_spec(
    axes: Optional[Tuple[Optional[str], ...]],
    shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    fsdp: bool = False,
    data_axes: Tuple[str, ...] = ("data",),
    extra_leading: int = 0,
) -> P:
    """PartitionSpec for one parameter. ``extra_leading`` accounts for
    stacked-layer leading dims added by scan-style init (replicated)."""
    if axes is None:
        return P()
    rules = dict(TP_RULES)
    if fsdp:
        for name in FSDP_EMBED:
            rules[name] = [tuple(a for a in data_axes if a in mesh.shape)]
    entries = [None] * extra_leading + list(axes)
    shape = tuple(shape)
    out = []
    used: set = set()
    for dim, name in zip(shape, entries):
        choice = None
        for cand in rules.get(name, []) if name else []:
            cand = tuple(a for a in cand if a in mesh.shape and a not in used)
            if not cand:
                continue
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                choice = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(choice)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(
    axes_tree: Any,
    values_tree: Any,
    mesh: Mesh,
    *,
    fsdp: bool = False,
    data_axes: Tuple[str, ...] = ("data",),
) -> Any:
    """Tree of NamedShardings parallel to the params tree.

    Handles scan-stacked parameters: if a value has more dims than its
    annotation, leading dims are treated as replicated stack dims.
    """

    def one(ax, val):
        extra = val.ndim - (len(ax) if ax is not None else 0)
        spec = resolve_spec(
            ax, val.shape, mesh, fsdp=fsdp, data_axes=data_axes,
            extra_leading=max(extra, 0),
        )
        return NamedSharding(mesh, spec)

    is_axes_leaf = lambda a: a is None or (
        isinstance(a, tuple) and all(x is None or isinstance(x, str) for x in a)
    )
    return jax.tree_util.tree_map(one, axes_tree, values_tree, is_leaf=is_axes_leaf)
