"""One execution substrate: the frozen :class:`ExecutionContext`.

Before this module, the repo had three divergent execution paths: the
trainer threaded an ``ApplyContext`` and relied on ambient ``ctx.shard``
constraints, the dry-run hand-built parameter/optimizer/cache shardings per
cell, and the serve engine was mesh-blind.  ``ExecutionContext`` collapses
them: it extends :class:`repro.models.mixer_api.ApplyContext` (so it flows
through the model stack unchanged, static under jit) with

  * the mesh (explicit, or ``None`` = single device / ambient),
  * the mixed-precision :class:`repro.common.policy.Policy`
    (``cast_compute`` at the top of the train step and the serve engine),
  * rule-driven sharding for *every* state tree — params, optimizer
    moments, and decode-cache pools — through one rule engine
    (``repro.distributed.sharding``; cache rules come from each mixer's
    ``cache_shard_axes`` spec),
  * long-prompt routing: :meth:`conv_backend_for` steers Hyena prefill
    through the sequence-parallel ``fft_sp`` backend when ``L`` exceeds
    the per-mesh threshold (context parallelism — "Scaling Context
    Requires Rethinking Attention", PAPERS.md).

Train, serve, dry-run, and the benchmarks all build one of these; sharding
decisions live here and in ``sharding.py``, nowhere else (DESIGN.md §9).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

from repro.common.policy import Policy
from repro.models.mixer_api import ApplyContext

# Auto threshold for routing prefill through the sequence-parallel FFT conv:
# route when the per-chip sequence chunk would exceed this many tokens, i.e.
# L >= SP_TOKENS_PER_CHIP * model_axis_size.  At 16K tokens/chip a 500K
# prompt routes on any mesh with >= 2-way model parallelism while ordinary
# serving prompts never do.  $REPRO_SP_MIN_LEN overrides the auto value
# (0 disables routing) when the context doesn't set sp_min_len explicitly.
SP_TOKENS_PER_CHIP = 16384
SP_ENV_VAR = "REPRO_SP_MIN_LEN"


def _mesh_or_ambient(mesh):
    if mesh is not None:
        return mesh
    from repro.distributed.ctx import current_mesh

    return current_mesh()


@dataclasses.dataclass(frozen=True)
class ExecutionContext(ApplyContext):
    """ApplyContext + mesh + sharding rules + mixed-precision policy.

    Frozen, hashable, static under jit — exactly like the base context.
    ``mesh`` (inherited) may be ``None``: every method then degrades to the
    single-device behavior (no shardings, ambient-mesh conv routing), so
    the same step functions run everywhere.
    """

    policy: Optional[Policy] = None  # None = caller-controlled dtypes
    fsdp: bool = False  # ZeRO-3 embed-family dims on the data axes
    data_axes: Tuple[str, ...] = ("data",)
    # sequence-parallel prefill threshold: None = auto (SP_TOKENS_PER_CHIP
    # per chip on the model axis), 0 = never route, else an explicit L
    sp_min_len: Optional[int] = None
    # context-parallel training: mesh axis the sequence dim of the batch
    # (and the residual stream) is sharded over.  None = CP off.  When set,
    # data_sharding shards dim 1 of (B, L) inputs over this axis, Hyena
    # convs route through the differentiable fft_sp backend, attention
    # mixers run the ring/masked-allgather path, and make_train_step's
    # halo exchange handles shifted-by-one targets (DESIGN.md §12).
    cp_axis: Optional[str] = None
    # reversible dual-stream training substrate (DESIGN.md §15): the scanned
    # block groups run as additive couplings whose custom_vjp reconstructs
    # activations from outputs — O(1) activation memory over the stacked
    # depth.  Training-only: serve/prefill/decode ignore the flag.  Composes
    # with cp_axis (both streams carry the same sequence-sharding pins) and
    # makes remat a no-op over the scanned depth (the custom VJP already
    # fixes the save set; tail layers still remat normally).
    reversible: bool = False

    def __post_init__(self):
        super().__post_init__()
        if self.reversible and self.unroll:
            raise ValueError(
                "reversible=True requires the scanned layer loop; "
                "unroll=True would re-trace every group and defeat the "
                "O(1)-memory custom_vjp — unset one of the two"
            )

    # ------------------------------------------------------------ precision
    def cast_compute(self, tree):
        """Policy-cast a tree (params at the top of a step); identity when
        no policy is set."""
        return tree if self.policy is None else self.policy.cast_compute(tree)

    @property
    def compute_dtype(self):
        return None if self.policy is None else self.policy.compute_dtype

    # ---------------------------------------------------------- mesh scope
    def scope(self):
        """Context manager making ``self.mesh`` the ambient mesh and
        ``self.cp_axis`` the ambient cp axis (no-op without either) —
        host-side entry point for engines and steps."""
        from repro.distributed import ctx as dctx

        stack = contextlib.ExitStack()
        if self.mesh is not None:
            stack.enter_context(dctx.use_mesh(self.mesh))
        if self.cp_axis is not None:
            stack.enter_context(dctx.use_cp_axis(self.cp_axis))
        return stack

    # ---------------------------------------------------- long-prompt conv
    def sp_threshold(self) -> Optional[int]:
        """Effective fft_sp routing threshold for this context's mesh:
        explicit ``sp_min_len`` > ``$REPRO_SP_MIN_LEN`` > the per-mesh auto
        value.  ``None`` when routing is off (no mesh / no model axis /
        a zero threshold)."""
        if self.sp_min_len == 0:
            return None
        mesh = _mesh_or_ambient(self.mesh)
        if mesh is None:
            return None
        P = mesh.shape.get("model", 1)
        if P <= 1:
            return None
        if self.sp_min_len is not None:
            return self.sp_min_len
        import os

        env = os.environ.get(SP_ENV_VAR)
        if env is not None:
            return int(env) or None
        return SP_TOKENS_PER_CHIP * P

    def conv_backend_for(self, L: int) -> Optional[str]:
        # context-parallel training: the sequence dim is sharded over
        # cp_axis, so the conv MUST run the sequence-parallel backend —
        # any local-FFT backend would all-gather L onto every chip
        if self.cp_axis is not None:
            mesh = _mesh_or_ambient(self.mesh)
            if mesh is not None and mesh.shape.get(self.cp_axis, 1) > 1:
                return "fft_sp"
        # an *explicitly configured* backend always wins unless the caller
        # also opted into routing by setting sp_min_len — auto-routing only
        # replaces the registry default, never a user/env selection
        if self.conv_backend is not None and self.sp_min_len is None:
            return self.conv_backend
        thresh = self.sp_threshold()
        if thresh is not None and L >= thresh:
            # non-divisible L pads to the next multiple inside spconv now;
            # no divisibility gate here anymore
            return "fft_sp"
        return self.conv_backend

    # ------------------------------------------------- rule-driven sharding
    def param_shardings(self, axes_tree, values_tree):
        """NamedShardings for an Ax-annotated params tree (None mesh →
        None: callers pass it straight to device_put / jit shardings)."""
        if self.mesh is None:
            return None
        from repro.distributed.sharding import param_shardings

        return param_shardings(
            axes_tree, values_tree, self.mesh, fsdp=self.fsdp,
            data_axes=self.data_axes,
        )

    def state_shardings(self, axes_tree, values_tree):
        """NamedShardings for an arbitrary (partially annotated) state
        tree — the generalized engine behind train state and caches."""
        if self.mesh is None:
            return None
        from repro.distributed.sharding import tree_shardings

        return tree_shardings(
            axes_tree, values_tree, self.mesh, fsdp=self.fsdp,
            data_axes=self.data_axes,
        )

    def train_state_shardings(self, param_axes, state):
        if self.mesh is None:
            return None
        from repro.distributed.sharding import train_state_shardings

        return train_state_shardings(
            param_axes, state, self.mesh, fsdp=self.fsdp,
            data_axes=self.data_axes,
        )

    def cache_shardings(self, cfg, caches):
        """Decode-cache NamedShardings, derived from each mixer's
        ``cache_shard_axes`` spec through the TP rule engine."""
        if self.mesh is None:
            return None
        from repro.models import lm

        return lm.cache_shardings(
            cfg, caches, self.mesh, fsdp=self.fsdp, data_axes=self.data_axes
        )

    def data_sharding(self, ndim: int, dim0: int, seq_len: Optional[int] = None):
        """Batch sharding for one input leaf: dim 0 over the data axes when
        divisible (the 'data' alias expands over pods), else replicated.
        Under ``cp_axis``, dim 1 (the sequence) additionally shards over the
        cp axis when ``seq_len`` is given and divisible — the entry point of
        context-parallel training: tokens arrive already sequence-sharded
        and no full-L array ever materializes per chip."""
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding

        from repro.distributed.sharding import batch_spec

        spec = batch_spec(
            self.mesh, ndim, dim0, seq_len,
            data_axes=self.data_axes, cp_axis=self.cp_axis,
        )
        return NamedSharding(self.mesh, spec)

    def place(self, tree, shardings):
        """device_put under this mesh (identity when meshless) — the one
        call sites use so state lands sharded before the first step."""
        if self.mesh is None or shardings is None:
            return tree
        import jax

        return jax.device_put(tree, shardings)


DEFAULT_EXECUTION = ExecutionContext()
