"""GPipe-style pipeline parallelism over a mesh axis (shard_map +
collective_permute).

The layer stack is split into S stages (stage s owns groups
[s·G/S, (s+1)·G/S)); a microbatched forward runs the classic GPipe
schedule: at tick t, stage s processes microbatch t−s, activations hop
stage→stage with ``lax.ppermute``.  Bubble fraction = (S−1)/(T+S−1).

This module is deliberately self-contained (a stage function is passed in)
so it composes with any block stack; tested on 4 host devices against the
unpipelined reference.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,  # (stage_params, x) -> x, applied by every stage
    stage_params,        # pytree with leading stage axis, sharded over `axis`
    x: jax.Array,        # (T, mb, ...) microbatched input (T microbatches)
    mesh: Mesh,
    axis: str = "pipe",
):
    """Returns stage_{S-1}(...stage_0(x)) for every microbatch, computed in
    the GPipe schedule. x lives fully on stage 0's shard at entry."""
    S = mesh.shape[axis]
    T = x.shape[0]

    def body(params_blk, x_blk):
        # params_blk: this stage's params (leading axis 1); x_blk: (T, mb, …)
        # on stage 0, zeros elsewhere.
        sid = jax.lax.axis_index(axis)
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_blk)
        n_ticks = T + S - 1
        mb_shape = x_blk.shape[1:]

        def tick(carry, t):
            buf = carry  # (mb, ...) activation entering this stage this tick
            # stage 0 injects microbatch t (if t < T); others use buf
            inject = jnp.where(t < T, 1, 0)
            mb_idx = jnp.clip(t, 0, T - 1)
            x_in = jnp.where(
                (sid == 0) & (inject == 1),
                x_blk[mb_idx],
                buf,
            )
            y = stage_fn(params_local, x_in)
            # pass activations downstream: stage s -> s+1 (last wraps to 0,
            # carrying the finished microbatch back as the output slot)
            perm = [(i, (i + 1) % S) for i in range(S)]
            nxt = jax.lax.ppermute(y, axis, perm)
            # outputs: the wrap-around value at stage 0 at tick t is the
            # finished microbatch t - (S - 1)
            return nxt, jnp.where(sid == 0, nxt, jnp.zeros_like(nxt))

        buf0 = jnp.zeros(mb_shape, x_blk.dtype)
        _, outs = jax.lax.scan(tick, buf0, jnp.arange(n_ticks))
        # finished microbatch m arrives at tick m + S - 1
        result = outs[S - 1 :]
        # only stage 0 collected real values (zeros elsewhere); psum over
        # the pipe axis broadcasts them so the result — declared replicated
        # by out_specs=P() — is actually correct on every device, not just
        # whichever shard the runtime assembles the global array from.
        return jax.lax.psum(result, axis)

    from repro.distributed.ctx import shard_map

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),  # params stage-sharded; x replicated
        out_specs=P(),
        check=False,  # axis_index-driven injects are device-varying by design
    )
    return fn(stage_params, x)
