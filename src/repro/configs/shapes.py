"""Assigned input-shape sets and ``input_specs()`` (ShapeDtypeStruct
stand-ins — weak-type-correct, shardable, no device allocation).

  train_4k     seq 4,096  × global_batch 256   → lowers train_step
  prefill_32k  seq 32,768 × global_batch 32    → lowers prefill forward
  decode_32k   seq 32,768 × global_batch 128   → lowers serve_step
  long_500k    seq 524,288 × global_batch 1    → lowers serve_step
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def token_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """Model *data* inputs for train/prefill as ShapeDtypeStructs."""
    B, L = shape.batch, shape.seq
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, L), jnp.int32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, L), jnp.int32)
    if cfg.frontend is not None and cfg.frontend_len:
        P = min(cfg.frontend_len, L)
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, P, cfg.d_model), jnp.bfloat16
        )
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """serve_step inputs: one new token + caches sized to shape.seq."""
    from repro.models import lm

    B = shape.batch
    caches = jax.eval_shape(
        lambda: lm.init_caches(cfg, B, shape.seq, dtype=jnp.bfloat16)
    )
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "caches": caches,
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return token_specs(cfg, shape)
