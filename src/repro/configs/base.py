"""Model configuration and the architecture registry.

``ModelConfig`` is the single composable description consumed by
``repro.models.lm``: a repeating ``pattern`` of token mixers + a channel
mixer (dense MLP or MoE), with per-family extras.  Each assigned
architecture registers its exact public-literature config in its own module
under ``repro/configs/`` and is selectable via ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # token-mixer pattern, repeated to n_layers ("attention", "local_attention",
    # "hyena", "ssd", "rglru").
    pattern: Tuple[str, ...] = ("attention",)
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp: str = "swiglu"  # swiglu | gelu | squared_relu
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    local_window: int = 0  # for local_attention layers
    tie_embeddings: bool = False
    # --- MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssd_head_dim: int = 64
    ssd_expand: int = 2
    # --- RG-LRU
    rnn_width: int = 0
    # --- Hyena
    hyena_order: int = 2
    hyena_filter_width: int = 64
    hyena_filter_depth: int = 4
    hyena_pos_dim: int = 65
    hyena_sine_freq: float = 14.0
    hyena_decay: tuple = (0.3, 1.5)  # (fast, slow) window decay-rate range
    hyena_max_support: int = 0  # >0: explicit short-FIR ablation
    # --- Hyena multi-hybrid variants (SE/MR/LI striping, arXiv:2503.01868)
    hyena_se_len: int = 8  # hyena_se explicit FIR filter length
    hyena_mr_support: int = 128  # hyena_mr fixed tap-grid support M
    # --- modality frontend stub: first `frontend_len` positions take
    # precomputed embeddings from input_specs() instead of token embeddings.
    frontend: Optional[str] = None  # "vit_stub" | "encodec_stub"
    frontend_len: int = 0
    # --- citation bookkeeping
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        # n_layers need not divide the pattern length: the remainder becomes
        # an unstacked "tail" (e.g. RecurrentGemma: 26 layers, pattern of 3).

    # ---------------------------------------------------------------- helpers
    @property
    def attention_free(self) -> bool:
        """No dense global-KV attention anywhere in the pattern (capability
        metadata from the TokenMixer registry)."""
        from repro.models.mixer_api import get_mixer

        return all(get_mixer(m).attention_free for m in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """Can run 500K-token decode without a dense global-KV attention."""
        from repro.models.mixer_api import get_mixer

        return all(get_mixer(m).subquadratic for m in self.pattern)

    def with_mixer(self, mixer: str) -> "ModelConfig":
        """The paper's drop-in swap: replace every mixer that is *not*
        attention-free (per registry metadata) with `mixer` (e.g. "hyena")."""
        from repro.models.mixer_api import get_mixer

        get_mixer(mixer)  # validate the target name against the registry
        new_pattern = tuple(
            mixer if not get_mixer(m).attention_free else m
            for m in self.pattern
        )
        return dataclasses.replace(
            self, pattern=new_pattern, name=f"{self.name}+{mixer}"
        )

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        plen = len(self.pattern)
        # keep a tail layer if the full config has one (pattern coverage)
        n_layers = plen + (1 if self.n_layers % plen else 0) if plen > 1 else 2
        d_model = 64
        n_heads = max(self.n_heads and 4, 0) or 0
        n_kv = min(self.n_kv_heads, 2) if self.n_kv_heads else 0
        return dataclasses.replace(
            self,
            name=f"{self.name}-smoke",
            n_layers=max(n_layers, plen),
            d_model=d_model,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=n_kv if n_kv else (2 if self.n_heads else 0),
            head_dim=16 if self.n_heads else 0,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=128,
            n_experts=4 if self.moe else 0,
            top_k=min(self.top_k, 2) if self.moe else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssd_head_dim=16 if self.ssm_state else 64,
            rnn_width=64 if self.rnn_width else 0,
            local_window=min(self.local_window, 32) if self.local_window else 0,
            hyena_filter_width=16,
            hyena_pos_dim=9,
            hyena_se_len=4,
            hyena_mr_support=16,
            frontend_len=8 if self.frontend else 0,
        )


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    # config-time validation: every pattern entry must name a registered
    # TokenMixer — a typo fails at import, not deep inside a forward pass.
    from repro.models.mixer_api import get_mixer

    for m in cfg.pattern:
        get_mixer(m)
    # multi-hybrid pattern rules (DESIGN.md §14): a striping is coherent
    # only when each variant's support is usable and the tiers are ordered
    # short < medium — otherwise an "SE-MR" stripe silently degenerates to
    # two copies of the same operator.
    if "hyena_se" in cfg.pattern and cfg.hyena_se_len < 2:
        raise ValueError(
            f"pattern {cfg.pattern} uses hyena_se but hyena_se_len="
            f"{cfg.hyena_se_len} < 2"
        )
    if "hyena_mr" in cfg.pattern and cfg.hyena_mr_support < 2:
        raise ValueError(
            f"pattern {cfg.pattern} uses hyena_mr but hyena_mr_support="
            f"{cfg.hyena_mr_support} < 2"
        )
    if (
        "hyena_se" in cfg.pattern
        and "hyena_mr" in cfg.pattern
        and cfg.hyena_mr_support <= cfg.hyena_se_len
    ):
        raise ValueError(
            f"multi-hybrid pattern {cfg.pattern} needs hyena_mr_support "
            f"({cfg.hyena_mr_support}) > hyena_se_len ({cfg.hyena_se_len}): "
            "the medium tier must cover longer lags than the short tier"
        )
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    return dict(_REGISTRY)
