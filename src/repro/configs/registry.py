"""Assigned architectures (exact public-literature configs) + the paper's
own Hyena LMs (Table A.4).  One ``--arch <id>`` per entry.

Every attention arch additionally supports the paper's drop-in swap via
``ModelConfig.with_mixer("hyena")`` (used for the `long_500k` cells of pure
full-attention archs — see DESIGN.md §5).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, register

# --------------------------------------------------------------- dense LMs

QWEN25_14B = register(ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab_size=152064, qkv_bias=True, mlp="swiglu", rope_theta=1000000.0,
    source="hf:Qwen/Qwen2.5-14B",
))

QWEN2_72B = register(ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, qkv_bias=True, mlp="swiglu", rope_theta=1000000.0,
    source="arXiv:2407.10671",
))

NEMOTRON4_15B = register(ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab_size=256000, mlp="squared_relu", rope_theta=10000.0,
    source="arXiv:2402.16819",
))

PHI4_MINI = register(ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=200064, mlp="swiglu", rope_theta=10000.0,
    source="arXiv:2412.08905",
))

# ---------------------------------------------------------------------- VLM

INTERNVL2_2B = register(ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92553, mlp="swiglu", rope_theta=10000.0,
    frontend="vit_stub", frontend_len=256,  # InternViT patch embeds (stub)
    source="arXiv:2404.16821",
))

# ---------------------------------------------------------------------- MoE

DBRX_132B = register(ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, mlp="swiglu", rope_theta=500000.0,
    moe=True, n_experts=16, top_k=4,
    source="hf:databricks/dbrx-base",
))

GRANITE_MOE = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, mlp="swiglu", rope_theta=10000.0,
    moe=True, n_experts=40, top_k=8,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
))

# ---------------------------------------------------------------------- SSM

MAMBA2_130M = register(ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, pattern=("ssd",), ssm_state=128, ssd_head_dim=64,
    ssd_expand=2, norm="rmsnorm", tie_embeddings=True,
    source="arXiv:2405.21060",
))

# ------------------------------------------------------------------- hybrid

RECURRENTGEMMA_2B = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    head_dim=256, vocab_size=256000, mlp="geglu", rope_theta=10000.0,
    pattern=("rglru", "rglru", "local_attention"), local_window=2048,
    rnn_width=2560,
    source="arXiv:2402.19427",
))

# -------------------------------------------------------------------- audio

MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, mlp="gelu", norm="layernorm", rope_theta=10000.0,
    frontend="encodec_stub", frontend_len=500,  # 10 s EnCodec prompt frames
    source="arXiv:2306.05284",
))

# ----------------------------------------------- the paper's own Hyena LMs
# Table A.4: depth/width/FFN width/filter FFN width+depth/sine freq.

def _hyena_lm(name, depth, width, ffn, order=2, vocab=50257):
    return register(ModelConfig(
        name=name, family="dense",
        n_layers=depth, d_model=width, n_heads=0, n_kv_heads=0, d_ff=ffn,
        vocab_size=vocab, pattern=("hyena",), hyena_order=order,
        hyena_filter_width=64, hyena_filter_depth=4, hyena_pos_dim=65,
        hyena_sine_freq=14.0, mlp="gelu",
        source="arXiv:2302.10866 Table A.4",
    ))


# StripedHyena-2-style multi-hybrid: short-explicit / medium-regularized /
# long-implicit hyena stripes plus one attention layer per repeat — the
# "convolutional multi-hybrid" layer allocation (no single operator wins
# every range at equal compute).
HYENA_MH_SMALL = register(ModelConfig(
    name="hyena-mh-small", family="hybrid",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_ff=1024,
    vocab_size=50257,
    pattern=("hyena_se", "hyena_mr", "hyena_li", "attention"),
    hyena_order=2, hyena_se_len=8, hyena_mr_support=128,
    hyena_filter_width=64, hyena_filter_depth=4, hyena_pos_dim=65,
    hyena_sine_freq=14.0, mlp="gelu",
    source="arXiv:2503.01868",
))

HYENA_125M = _hyena_lm("hyena-125m", 12, 768, 3072, order=3)
HYENA_125M_SLIM = _hyena_lm("hyena-125m-slim", 18, 768, 1536, order=3)
HYENA_153M = _hyena_lm("hyena-153m", 18, 864, 1728, order=2)
HYENA_355M = _hyena_lm("hyena-355m", 36, 1024, 2048, order=2)
HYENA_1_3B = _hyena_lm("hyena-1.3b", 36, 2048, 4096, order=2)

ASSIGNED = [
    "qwen2.5-14b", "qwen2-72b", "nemotron-4-15b", "phi4-mini-3.8b",
    "internvl2-2b", "dbrx-132b", "granite-moe-3b-a800m", "mamba2-130m",
    "recurrentgemma-2b", "musicgen-large",
]
