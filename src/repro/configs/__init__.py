from repro.configs.base import ModelConfig, get_config, register, list_configs  # noqa: F401
from repro.configs import registry as _registry  # noqa: F401  (populates the registry)
