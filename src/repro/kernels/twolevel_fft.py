"""Overlapped two-level (inner R / outer S) FFT causal-conv Pallas kernel.

The four-step block FFT (``repro.core.blockfft``) already puts every DFT
FLOP on the MXU; what it leaves on the table is *overlap*: each stage
(inner R-point DFTs, twiddle, outer S-point DFTs, pointwise filter
multiply, inverse) runs as a separate XLA op, so the activation makes a
full HBM round-trip between stages.  This kernel runs the whole two-level
schedule inside ONE ``pallas_call``:

  * the grid iterates ``(channel_block, r_chunk)`` — Pallas's software
    pipeline double-buffers the next chunk's HBM→VMEM streams (input slab,
    DFT column block) against the current chunk's spectrum matmuls, so HBM
    transfers overlap MXU compute;
  * ``overlap`` is the pipeline depth: the inner-block DFT is split into
    ``overlap`` accumulation chunks over the R rows (smaller in-flight
    transfers, deeper overlap), accumulated into a VMEM spectrum scratch;
  * on the last chunk the twiddle, outer S-point DFT, pointwise filter
    multiply, inverse transform, and the gated-fusion finalize (skip-add in
    fp32 → downcast → gate multiply in the output dtype, the DESIGN.md §7
    bit-identity policy) all happen in VMEM — the conv output hits HBM
    exactly once.

Complex arithmetic is carried as explicit (re, im) fp32 planes (Pallas TPU
has no complex lanes); the filter spectrum is precomputed outside the
kernel with the same factor split, so the kernel's pointwise stage matches
``blockfft_causal_conv``'s spectrum layout term for term.

Off-TPU (CI) the same ``(R, S)`` schedule degrades to the plain
``blockfft`` path — identical math, no interpret-mode timing theater; the
kernel body itself is pinned by interpret-mode tests on small shapes
(tests/test_conv_backends_prop.py).  The ``(R, S)`` split, channel tile,
and overlap depth are autotunable as the ``"twolevel"`` plan kind
(``core.autotune``; consulted by the ``blockfft_overlap`` registration in
``core.conv_api``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.blockfft import _dft_mats, _factor, _four_step_fft
from repro.kernels.platform import on_tpu


def _largest_divisor_leq(n: int, k: int) -> int:
    k = max(1, min(k, n))
    while n % k:
        k -= 1
    return k


def twolevel_candidates(shape, limit: int = 3):
    """Autotune search space for the ``"twolevel"`` plan kind: valid
    ``(R, S)`` splits of the padded length × overlap depth × channel tile.
    Every point computes the identical convolution (factor splits
    reassociate the DFT sums; overlap/tile only re-chunk the schedule), so
    the search is semantics-preserving by construction — the
    ``core.autotune`` contract."""
    from repro.core.blockfft import factor_candidates
    from repro.core.fftconv import next_fast_len

    B, L, D = shape
    N = next_fast_len(2 * L - 1)
    cands = []
    for R, S in factor_candidates(N, limit=limit):
        for ov in (2, 4):
            if R % ov:
                continue
            for bd in (64, 128):
                cands.append(
                    {"factors": [R, S], "overlap": ov, "block_d": bd}
                )
    # degenerate split vocabulary (tiny N): keep at least the default point
    if not cands:
        R, S = _factor(N)
        cands.append({"factors": [R, S], "overlap": 1, "block_d": 128})
    return cands


def _twolevel_kernel(
    u_ref,       # (B, Rc, S, bd) fp32 — r-chunk of the reshaped padded input
    frre_c_ref,  # (R, Rc) inner DFT column block for this r-chunk (re)
    frim_c_ref,  # (R, Rc) (im)
    frre_ref,    # (R, R) full inner DFT — the inverse needs every column
    frim_ref,    # (R, R)
    twre_ref,    # (R, S) twiddle W_N^{k1 s} (re)
    twim_ref,    # (R, S) (im)
    fsre_ref,    # (S, S) outer DFT (re)
    fsim_ref,    # (S, S) (im)
    hre_ref,     # (R, S, bd) filter spectrum block (re)
    him_ref,     # (R, S, bd) (im)
    ui_ref,      # (B, L, bd) fp32 original input (skip term, finalize)
    skip_ref,    # (1, bd) fp32
    g_ref,       # (B, L, bd) gate (output dtype; dummy row when ungated)
    o_ref,       # (B, L, bd) output
    accre_ref, accim_ref,  # VMEM (B, R, S, bd) fp32 spectrum accumulators
    *, N: int, L: int, overlap: int, gated: bool,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        accre_ref[...] = jnp.zeros_like(accre_ref)
        accim_ref[...] = jnp.zeros_like(accim_ref)

    # ---- stage 1 (every pipeline step): inner-DFT accumulation.  The
    # next chunk's input slab / DFT column block stream HBM→VMEM while
    # this chunk's matmuls occupy the MXU — the overlap this kernel
    # exists for.  Real input, so a chunk costs two real matmuls.
    a = u_ref[...]
    accre_ref[...] += jnp.einsum(
        "kr,brsd->bksd", frre_c_ref[...], a,
        preferred_element_type=jnp.float32,
    )
    accim_ref[...] += jnp.einsum(
        "kr,brsd->bksd", frim_c_ref[...], a,
        preferred_element_type=jnp.float32,
    )

    # ---- stages 2–5 (last step only): twiddle → outer DFT → pointwise
    # filter → inverse transform → gated finalize, all in VMEM.
    @pl.when(c == overlap - 1)
    def _finalize():
        # twiddle W_N^{k1 s} (elementwise complex multiply)
        twre = twre_ref[...][None, :, :, None]
        twim = twim_ref[...][None, :, :, None]
        bre, bim = accre_ref[...], accim_ref[...]
        ure = bre * twre - bim * twim
        uim = bre * twim + bim * twre
        # outer S-point DFT: C[k1, j] = Σ_s U[k1, s] · FS[s, j]
        fsre, fsim = fsre_ref[...], fsim_ref[...]
        dot = functools.partial(
            jnp.einsum, "bksd,sj->bkjd",
            preferred_element_type=jnp.float32,
        )
        cre = dot(ure, fsre) - dot(uim, fsim)
        cim = dot(ure, fsim) + dot(uim, fsre)
        # pointwise filter multiply in the spectrum (same layout as
        # blockfft._four_step_fft: X[k1 + k2·R] = C[k1, k2])
        hre = hre_ref[...][None]
        him = him_ref[...][None]
        yre = cre * hre - cim * him
        yim = cre * him + cim * hre
        # inverse outer DFT: D[k1, s] = Σ_j Y[k1, j] · conj(FS)[s, j]
        idot = functools.partial(
            jnp.einsum, "bkjd,sj->bksd",
            preferred_element_type=jnp.float32,
        )
        dre = idot(yre, fsre) + idot(yim, fsim)
        dim = idot(yim, fsre) - idot(yre, fsim)
        # conjugate twiddle (elementwise)
        ere = dre * twre + dim * twim
        eim = dim * twre - dre * twim
        # inverse inner DFT — conv output is real by construction, so only
        # the real plane: Re A[r] = Σ_k (FRre[k,r]·Ere[k] + FRim[k,r]·Eim[k])
        rdot = functools.partial(
            jnp.einsum, "kr,bksd->brsd",
            preferred_element_type=jnp.float32,
        )
        are = rdot(frre_ref[...], ere) + rdot(frim_ref[...], eim)
        B = are.shape[0]
        bd = are.shape[-1]
        # (B, R, S, bd) row-major == x[r·S + s]: inverse of the forward
        # reshape, so this is exactly the length-N time axis
        y = are.reshape(B, N, bd)[:, :L, :] * (1.0 / N)
        # gated-fusion finalize (fftconv._fused_epilogue policy): skip-add
        # in fp32, downcast, THEN gate in the output dtype — bit-identical
        # to the two-pass gate-after schedule
        y = y + ui_ref[...] * skip_ref[0][None, None, :]
        y = y.astype(o_ref.dtype)
        if gated:
            y = y * g_ref[...].astype(o_ref.dtype)
        o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("factors", "block_d", "overlap", "interpret"),
)
def twolevel_fft_conv(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L)
    skip: Optional[jax.Array] = None,  # (D,)
    gate: Optional[jax.Array] = None,  # (B, L, D) elementwise output gate
    *,
    factors: Optional[Tuple[int, int]] = None,  # autotuned (R, S) split
    block_d: int = 128,
    overlap: int = 2,  # inner-DFT pipeline depth (clamped to divide R)
    interpret: bool | None = None,  # True forces the Pallas body (tests)
) -> jax.Array:
    """Two-level overlapped FFT causal conv (ConvBackend contract).

    On TPU (or with ``interpret=True``) runs the single-``pallas_call``
    pipelined schedule; elsewhere degrades to ``blockfft_causal_conv``
    with the same ``(R, S)`` split — identical math, so the CPU CI sweep
    exercises the real schedule's numerics rather than interpret-mode
    theater.
    """
    from repro.core.blockfft import blockfft_causal_conv
    from repro.core.fftconv import next_fast_len

    B, L, D = u.shape
    N = next_fast_len(2 * L - 1)
    if factors is not None and factors[0] * factors[1] != N:
        factors = None  # stale plan for a different padded length
    if not (on_tpu() or interpret):
        return blockfft_causal_conv(u, h, skip, gate, factors=factors)

    R, S, FR, FS, TW = _dft_mats(N, factors)
    ov = _largest_divisor_leq(R, overlap)
    bd = max(1, min(block_d, D))
    pad_d = (-D) % bd
    out_dtype = u.dtype
    u32 = u.astype(jnp.float32)
    h32 = h.astype(jnp.float32)
    g_in = gate
    if pad_d:
        u32 = jnp.pad(u32, ((0, 0), (0, 0), (0, pad_d)))
        h32 = jnp.pad(h32, ((0, pad_d), (0, 0)))
        if g_in is not None:
            g_in = jnp.pad(g_in, ((0, 0), (0, 0), (0, pad_d)))
    Dp = D + pad_d
    skip32 = (
        jnp.zeros((Dp,), jnp.float32) if skip is None
        else jnp.pad(skip.astype(jnp.float32), (0, pad_d))
    )
    # padded input in the (B, R, S, D) four-step layout: x[r·S + s] = A[r, s]
    up = jnp.pad(u32, ((0, 0), (0, N - L), (0, 0)))
    u4 = up.reshape(B, R, S, Dp)
    # filter spectrum, precomputed with the SAME split (one small transform
    # per call, shared across the batch and the grid)
    hp = jnp.pad(h32.T, ((0, N - L), (0, 0)))[None]  # (1, N, Dp)
    H = _four_step_fft(hp, N, (R, S))[0]  # (R, S, Dp) complex64
    gated = g_in is not None
    g_arg = g_in if gated else jnp.zeros((B, 1, Dp), out_dtype)
    Rc = R // ov

    grid = (Dp // bd, ov)
    out = pl.pallas_call(
        functools.partial(
            _twolevel_kernel, N=N, L=L, overlap=ov, gated=gated,
        ),
        grid=grid,
        in_specs=[
            # r-chunk of the reshaped input (streams in per pipeline step)
            pl.BlockSpec((B, Rc, S, bd), lambda d, c: (0, c, 0, d)),
            # inner DFT column block for this r-chunk
            pl.BlockSpec((R, Rc), lambda d, c: (0, c)),
            pl.BlockSpec((R, Rc), lambda d, c: (0, c)),
            # full inner DFT (the inverse at finalize needs every column)
            pl.BlockSpec((R, R), lambda d, c: (0, 0)),
            pl.BlockSpec((R, R), lambda d, c: (0, 0)),
            # twiddle + outer DFT (whole matrices, block-pinned)
            pl.BlockSpec((R, S), lambda d, c: (0, 0)),
            pl.BlockSpec((R, S), lambda d, c: (0, 0)),
            pl.BlockSpec((S, S), lambda d, c: (0, 0)),
            pl.BlockSpec((S, S), lambda d, c: (0, 0)),
            # filter spectrum block for this channel tile
            pl.BlockSpec((R, S, bd), lambda d, c: (0, 0, d)),
            pl.BlockSpec((R, S, bd), lambda d, c: (0, 0, d)),
            # original input (skip term) + skip + gate, read at finalize
            pl.BlockSpec((B, L, bd), lambda d, c: (0, 0, d)),
            pl.BlockSpec((1, bd), lambda d, c: (0, d)),
            pl.BlockSpec(
                (B, L if gated else 1, bd), lambda d, c: (0, 0, d)
            ),
        ],
        out_specs=pl.BlockSpec((B, L, bd), lambda d, c: (0, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, L, Dp), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((B, R, S, bd), jnp.float32),
            pltpu.VMEM((B, R, S, bd), jnp.float32),
        ],
        interpret=bool(interpret) if interpret is not None else False,
    )(
        u4,
        jnp.asarray(FR.real), jnp.asarray(FR.imag),
        jnp.asarray(FR.real), jnp.asarray(FR.imag),
        jnp.asarray(TW.real), jnp.asarray(TW.imag),
        jnp.asarray(FS.real), jnp.asarray(FS.imag),
        jnp.asarray(H.real), jnp.asarray(H.imag),
        u32[:, :L, :], skip32.reshape(1, -1), g_arg,
    )
    if pad_d:
        out = out[:, :, :D]
    return out
