"""Fused short depthwise causal conv (+ optional gate) Pallas TPU kernel.

This is Algorithm 1 step 2 of the paper (the explicit width-3 FIR applied to
the (N+1)·D Hyena projections), optionally fused with the element-wise gate
of the Hyena recurrence — the two VPU-bound elementwise stages collapse into
one HBM round-trip.

Tiling: grid (B, L/block_l, D/block_d).  The causal halo (K-1 trailing rows
of the previous L-block) is delivered through a second BlockSpec view of the
same input with ``index_map = i-1`` (clamped at 0 and masked), avoiding any
overlapping-block machinery.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret


def _short_conv_kernel(u_ref, uprev_ref, w_ref, g_ref, o_ref, *, K: int, gated: bool):
    i = pl.program_id(1)  # L-block index
    u = u_ref[0].astype(jnp.float32)  # (block_l, block_d)
    halo = uprev_ref[0, -(K - 1):, :].astype(jnp.float32)  # (K-1, block_d)
    halo = jnp.where(i == 0, 0.0, halo)
    full = jnp.concatenate([halo, u], axis=0)  # (block_l + K - 1, block_d)
    w = w_ref[...].astype(jnp.float32)  # (K, block_d)
    Lb = u.shape[0]
    y = jnp.zeros_like(u)
    for k in range(K):
        # tap k multiplies u shifted back by k: rows [K-1-k : K-1-k+Lb)
        y = y + full[K - 1 - k : K - 1 - k + Lb, :] * w[k][None, :]
    if gated:
        y = y * g_ref[0].astype(jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_l", "block_d", "interpret")
)
def short_conv_gate(
    u: jax.Array,  # (B, L, D)
    w: jax.Array,  # (D, K)
    gate: jax.Array | None = None,  # (B, L, D)
    *,
    block_l: int = 512,
    block_d: int = 128,
    interpret: bool | None = None,  # None => interpret off-TPU only
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    B, L, D = u.shape
    K = w.shape[1]
    block_l = min(block_l, L)
    block_d = min(block_d, D)
    pad_l = (-L) % block_l
    pad_d = (-D) % block_d
    if pad_l or pad_d:
        u = jnp.pad(u, ((0, 0), (0, pad_l), (0, pad_d)))
        if gate is not None:
            gate = jnp.pad(gate, ((0, 0), (0, pad_l), (0, pad_d)))
    wT = w.T  # (K, D)
    if pad_d:
        wT = jnp.pad(wT, ((0, 0), (0, pad_d)))
    Lp, Dp = u.shape[1], u.shape[2]
    gated = gate is not None
    g_in = gate if gated else jnp.zeros((B, 1, Dp), u.dtype)
    grid = (B, Lp // block_l, Dp // block_d)
    out = pl.pallas_call(
        functools.partial(_short_conv_kernel, K=K, gated=gated),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_l, block_d), lambda b, i, d: (b, i, d)),
            # previous L-block (halo source); clamped at the first block
            pl.BlockSpec(
                (1, block_l, block_d),
                lambda b, i, d: (b, jnp.maximum(i - 1, 0), d),
            ),
            pl.BlockSpec((K, block_d), lambda b, i, d: (0, d)),
            pl.BlockSpec(
                (1, block_l if gated else 1, block_d),
                (lambda b, i, d: (b, i, d)) if gated else (lambda b, i, d: (b, 0, d)),
            ),
        ],
        out_specs=pl.BlockSpec((1, block_l, block_d), lambda b, i, d: (b, i, d)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=interpret,
    )(u, u, wT, g_in)
    if pad_l or pad_d:
        out = out[:, :L, :D]
    return out
