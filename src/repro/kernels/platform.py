"""Shared platform detection for the Pallas kernel entry points.

Every raw kernel wrapper defaults ``interpret=None`` → "interpret unless we
are actually on a TPU".  The old hard-coded ``interpret=True`` default meant
direct callers (anyone bypassing :mod:`repro.kernels.ops`) silently ran the
interpreter on real hardware — a correctness-preserving but catastrophic
slowdown.  ``interpret`` stays a jit-static argument, so ``None`` is resolved
here exactly once per trace.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → auto-detect: native lowering on TPU, interpreter elsewhere."""
    return (not on_tpu()) if interpret is None else interpret
