"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU the Pallas lowering runs natively; everywhere else
(this CPU container) kernels execute via ``interpret=True`` so the *same
kernel body* is validated.  ``use_kernel=False`` (or platform == cpu inside
jit-of-dryrun lowerings where interpret overhead matters) falls back to the
pure-jnp oracle in :mod:`repro.kernels.ref` — bit-compatible semantics by
construction (tested).
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import short_conv as _sc
from repro.kernels import toeplitz_conv as _tc


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def short_conv_gate(u, w, gate=None, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _sc.short_conv_gate(u, w, gate, interpret=not _on_tpu(), **kw)
    return _ref.short_conv_gate(u, w, gate)


def toeplitz_conv(u, h, skip=None, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _tc.toeplitz_conv(u, h, skip, interpret=not _on_tpu(), **kw)
    return _ref.toeplitz_conv(u, h, skip, n_chunk_diags=kw.get("n_chunk_diags"))


def flash_attention(q, k, v, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _fa.flash_attention(q, k, v, interpret=not _on_tpu(), **kw)
    kw.pop("blk_q", None), kw.pop("blk_k", None)
    return _ref.flash_attention(q, k, v, **kw)


def rmsnorm(x, g, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _rn.rmsnorm(x, g, interpret=not _on_tpu(), **kw)
    return _ref.rmsnorm(x, g, eps=kw.get("eps", 1e-6))
