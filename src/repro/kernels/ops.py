"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU the Pallas lowering runs natively; everywhere else
(this CPU container) kernels execute via ``interpret=True`` so the *same
kernel body* is validated.  ``use_kernel=False`` (or platform == cpu inside
jit-of-dryrun lowerings where interpret overhead matters) falls back to the
pure-jnp oracle in :mod:`repro.kernels.ref` — bit-compatible semantics by
construction (tested).

Tile sizes come from the autotuned conv-plan cache (:mod:`repro.core.
autotune`, ``$REPRO_AUTOTUNE``): this module is the consultation point, so
model code never names a ``block_l``/``chunk``/``block_d``.  Explicit kwargs
from the caller always override the plan.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import autotune
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import short_conv as _sc
from repro.kernels import toeplitz_conv as _tc
from repro.kernels.platform import on_tpu as _on_tpu


def _dedup(cands):
    out = []
    for c in cands:
        if c not in out:
            out.append(c)
    return out


def _short_conv_plan(shape, dtype, K: int, gated: bool):
    B, L, D = shape
    cands = _dedup(
        {"block_l": min(bl, L), "block_d": min(bd, D)}
        for bl in (128, 256, 512, 1024)
        for bd in (128, 256)
    )

    def run(**tiles):
        u = jnp.ones(shape, dtype)
        w = jnp.ones((D, K), jnp.float32)
        g = jnp.ones(shape, dtype) if gated else None
        return _sc.short_conv_gate(u, w, g, **tiles)

    # K changes the halo width and arithmetic intensity — different K,
    # different plan (the toeplitz band is keyed for the same reason)
    kind = ("short_conv_gated" if gated else "short_conv") + f"_k{K}"
    return autotune.plan_for(kind, shape, dtype, candidates=cands, run=run)


def _toeplitz_plan(shape, dtype, gated: bool, n_chunk_diags):
    B, L, D = shape
    cands = _dedup(
        {"chunk": min(c, L), "block_d": min(bd, D)}
        for c in (64, 128, 256)
        for bd in (128, 256)
    )

    def run(**tiles):
        u = jnp.ones(shape, dtype)
        h = jnp.ones((D, L), jnp.float32)
        g = jnp.ones(shape, dtype) if gated else None
        return _tc.toeplitz_conv(
            u, h, None, g, n_chunk_diags=n_chunk_diags, **tiles
        )

    # the band is an approximation knob the *caller* chose — it keys the
    # plan (different compute shape) but is never searched over
    kind = "toeplitz" + ("_gated" if gated else "")
    if n_chunk_diags is not None:
        kind += f"_band{n_chunk_diags}"
    return autotune.plan_for(kind, shape, dtype, candidates=cands, run=run)


def short_conv_gate(u, w, gate=None, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        # mode() guard first: with autotune off (the default) the hot path
        # must not pay for candidate construction on every dispatch
        if (autotune.mode() != "off"
                and "block_l" not in kw and "block_d" not in kw):
            plan = _short_conv_plan(
                u.shape, u.dtype, w.shape[1], gate is not None
            )
            if plan:
                kw = {**plan, **kw}
        return _sc.short_conv_gate(u, w, gate, **kw)
    return _ref.short_conv_gate(u, w, gate)


def toeplitz_conv(u, h, skip=None, gate=None, *,
                  use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        if (autotune.mode() != "off"
                and "chunk" not in kw and "block_d" not in kw):
            plan = _toeplitz_plan(
                u.shape, u.dtype, gate is not None, kw.get("n_chunk_diags")
            )
            if plan:
                kw = {**plan, **kw}
        return _tc.toeplitz_conv(u, h, skip, gate, **kw)
    return _ref.toeplitz_conv(
        u, h, skip, gate, n_chunk_diags=kw.get("n_chunk_diags")
    )


def flash_attention(q, k, v, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _fa.flash_attention(q, k, v, **kw)
    kw.pop("blk_q", None), kw.pop("blk_k", None)
    return _ref.flash_attention(q, k, v, **kw)


def rmsnorm(x, g, *, use_kernel: bool | None = None, **kw):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _rn.rmsnorm(x, g, **kw)
    return _ref.rmsnorm(x, g, eps=kw.get("eps", 1e-6))
