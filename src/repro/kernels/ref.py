"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(``tests/test_kernels_*`` sweep shapes/dtypes and ``assert_allclose``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def short_conv_gate(
    u: jax.Array,  # (B, L, D)
    w: jax.Array,  # (D, K)
    gate: Optional[jax.Array] = None,  # (B, L, D) elementwise gate
) -> jax.Array:
    """y = gate ⊙ causal_depthwise_conv(u, w).  fp32 accumulation."""
    B, L, D = u.shape
    K = w.shape[1]
    u32 = u.astype(jnp.float32)
    y = jnp.zeros((B, L, D), jnp.float32)
    for k in range(K):
        shifted = u32 if k == 0 else jnp.pad(u32, ((0, 0), (k, 0), (0, 0)))[:, :L]
        y = y + shifted * w[:, k].astype(jnp.float32)[None, None, :]
    if gate is not None:
        y = y * gate.astype(jnp.float32)
    return y.astype(u.dtype)


def toeplitz_conv(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L) causal filter taps
    skip: Optional[jax.Array] = None,  # (D,)
    gate: Optional[jax.Array] = None,  # (B, L, D) elementwise output gate
    n_chunk_diags: Optional[int] = None,  # banded support: K block diagonals
    chunk: int = 128,
) -> jax.Array:
    """Causal depthwise long conv; optionally banded to ``n_chunk_diags``
    *block* diagonals of the Toeplitz operator: entries S[t, t'] with
    ``t//chunk - t'//chunk >= n_chunk_diags`` are dropped — the exact
    semantics of the kernel's chunk-diagonal truncation for exp-decay-
    windowed Hyena filters."""
    B, L, D = u.shape
    h = h.astype(jnp.float32)
    t = jnp.arange(L)
    idx = t[:, None] - t[None, :]
    S = jnp.where(idx >= 0, h[:, jnp.clip(idx, 0, L - 1)], 0.0)  # (D, L, L)
    if n_chunk_diags is not None:
        blk = t[:, None] // chunk - t[None, :] // chunk
        S = jnp.where((blk < n_chunk_diags)[None], S, 0.0)
    y = jnp.einsum("dij,bjd->bid", S, u.astype(jnp.float32))
    if skip is not None:
        y = y + u.astype(jnp.float32) * skip.astype(jnp.float32)[None, None, :]
    # downcast before the gate: the gated conv must equal the two-pass
    # schedule gate * conv(u) bit-for-bit (core.fftconv._fused_epilogue)
    y = y.astype(u.dtype)
    if gate is not None:
        y = y * gate.astype(u.dtype)
    return y


def flash_attention(
    q: jax.Array,  # (B, H, Lq, Dh)
    k: jax.Array,  # (B, Hkv, Lk, Dh)
    v: jax.Array,  # (B, Hkv, Lk, Dh)
    causal: bool = True,
    window: Optional[int] = None,  # local attention window (None = global)
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference softmax attention with GQA (H % Hkv == 0), causal and
    optional sliding-window masking.  fp32 softmax."""
    B, H, Lq, Dh = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, G, Lq, Dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)  # (B,Hkv,G,Lq,Lk)
    Lk = kf.shape[2]
    # causal offset: query i attends keys <= i + (Lk - Lq)  (decode case)
    iq = jnp.arange(Lq)[:, None] + (Lk - Lq)
    ik = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask = mask & (ik <= iq)
    if window is not None:
        mask = mask & (ik > iq - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, H, Lq, Dh).astype(q.dtype)


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-6) -> jax.Array:
    """y = x / rms(x) * (1 + g); rms over the last dim in fp32."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * (1.0 + g.astype(jnp.float32))
    return y.astype(x.dtype)
