"""Chunked block-Toeplitz causal long-conv Pallas TPU kernel.

This is the MXU-native adaptation of the paper's fused CUDA FFTConv
(DESIGN.md §2).  The causal depthwise conv ``y_t = Σ_lag h_lag · u_{t-lag}``
is chunked into C-sized blocks; the contribution of lag-chunk ``k = i - j``
to output chunk ``i`` is a per-channel C×C Toeplitz matmul

    y_i[d] += T_k[d] @ u_j[d],     T_k[d][a, b] = h[d][kC + a - b]

evaluated as a channel-batched ``dot_general`` on the MXU.  Hyena filters are
exponential-decay windowed, so truncating to ``n_chunk_diags`` chunk
diagonals (banded support) turns the O(L²/C) schedule into O(L·K) while
keeping every FLOP on the systolic array instead of the VPU-bound FFT.

Causality inside the diagonal block is obtained *structurally*: the filter is
front-padded with C zeros, so negative lags index into the zero pad — no
masks in the inner loop.

The Hyena recurrence's data-controlled gate ``xⁿ ⊙ conv(v)`` fuses into the
kernel: the gate chunk rides in through one extra BlockSpec and multiplies
the downcast accumulator at finalize, in VMEM, so the gated conv output
hits HBM exactly once — the unfused path wrote the conv output and re-read
it for a separate full-tensor gate multiply.  The multiply happens in the
*output* dtype, bit-identical to the two-pass schedule it replaces
(core.fftconv._fused_epilogue documents the policy).

Grid: (d_block, i_chunk, j_rel) with j_rel (the chunk diagonal) innermost;
fp32 VMEM scratch accumulator, finalized on the last diagonal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import resolve_interpret


def _toeplitz_kernel(
    u_ref, ha_ref, hb_ref, ui_ref, skip_ref, g_ref, o_ref, acc_ref,
    *, C: int, K: int, gated: bool,
):
    r = pl.program_id(2)  # chunk diagonal (j_rel); j = i - r
    i = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        ui = ui_ref[...].astype(jnp.float32)  # (B, C, blk_d), u chunk i
        skip = skip_ref[0].astype(jnp.float32)  # (blk_d,)
        acc_ref[...] = ui.transpose(2, 1, 0) * skip[:, None, None]

    @pl.when(r <= i)
    def _accumulate():
        taps = jnp.concatenate(
            [ha_ref[...], hb_ref[...]], axis=1
        ).astype(jnp.float32)  # (blk_d, 2C); padded coords kC .. kC+2C-1
        a = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        b = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
        idx = C + a - b  # local tap index in [1, 2C-1]
        T = jnp.take(taps, idx, axis=1)  # (blk_d, C, C)
        u = u_ref[...].astype(jnp.float32)  # (B, C, blk_d), u chunk j
        ut = u.transpose(2, 1, 0)  # (blk_d, C, B)
        acc_ref[...] += jax.lax.dot_general(
            T, ut, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )

    @pl.when(r == K - 1)
    def _finalize():
        y = acc_ref[...].transpose(2, 1, 0).astype(o_ref.dtype)
        if gated:
            # gate applied to the *downcast* accumulator, in VMEM: saves
            # the HBM round-trip of the two-pass schedule while staying
            # bit-identical to it (gate * conv in the output dtype —
            # fftconv._fused_epilogue documents why)
            y = y * g_ref[...].astype(o_ref.dtype)
        o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "block_d", "n_chunk_diags", "interpret"),
)
def toeplitz_conv(
    u: jax.Array,  # (B, L, D)
    h: jax.Array,  # (D, L)
    skip: jax.Array | None = None,  # (D,)
    gate: jax.Array | None = None,  # (B, L, D) elementwise output gate
    *,
    chunk: int = 128,
    block_d: int = 128,
    n_chunk_diags: int | None = None,
    interpret: bool | None = None,  # None => interpret off-TPU only
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    B, L, D = u.shape
    C = min(chunk, L)
    pad_l = (-L) % C
    block_d = min(block_d, D)
    pad_d = (-D) % block_d
    if pad_l or pad_d:
        u = jnp.pad(u, ((0, 0), (0, pad_l), (0, pad_d)))
        h = jnp.pad(h, ((0, pad_d), (0, pad_l)))
        if gate is not None:
            gate = jnp.pad(gate, ((0, 0), (0, pad_l), (0, pad_d)))
    if skip is None:
        skip = jnp.zeros((h.shape[0],), jnp.float32)
    elif pad_d:
        skip = jnp.pad(skip, (0, pad_d))
    Lp, Dp = u.shape[1], u.shape[2]
    n_chunks = Lp // C
    K = n_chunks if n_chunk_diags is None else min(n_chunk_diags, n_chunks)
    # front-pad C zeros => negative lags hit zeros (structural causality);
    # the last diagonal's high block needs one extra C of zeros at the end.
    hpad = jnp.pad(h, ((0, 0), (C, C)))  # (Dp, Lp + 2C)
    gated = gate is not None
    g_in = gate if gated else jnp.zeros((B, 1, Dp), u.dtype)
    grid = (Dp // block_d, n_chunks, K)
    out = pl.pallas_call(
        functools.partial(_toeplitz_kernel, C=C, K=K, gated=gated),
        grid=grid,
        in_specs=[
            # u chunk j = i - r (clamped; masked when r > i)
            pl.BlockSpec(
                (B, C, block_d),
                lambda d, i, r: (0, jnp.maximum(i - r, 0), d),
            ),
            # filter window low/high blocks for lag-chunk k = r
            pl.BlockSpec((block_d, C), lambda d, i, r: (d, r)),
            pl.BlockSpec((block_d, C), lambda d, i, r: (d, r + 1)),
            # u chunk i (skip term, read at r == 0)
            pl.BlockSpec((B, C, block_d), lambda d, i, r: (0, i, d)),
            pl.BlockSpec((1, block_d), lambda d, i, r: (0, d)),
            # gate chunk i (read at finalize; dummy row when ungated)
            pl.BlockSpec(
                (B, C if gated else 1, block_d),
                (lambda d, i, r: (0, i, d)) if gated
                else (lambda d, i, r: (0, 0, d)),
            ),
        ],
        out_specs=pl.BlockSpec((B, C, block_d), lambda d, i, r: (0, i, d)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, C, B), jnp.float32)],
        interpret=interpret,
    )(u, hpad, hpad, u, skip.reshape(1, -1), g_in)
    if pad_l or pad_d:
        out = out[:, :L, :D]
    return out
