"""Causal GQA flash attention Pallas TPU kernel (the baseline Transformer's
hot-spot; paper §4.4 benchmarks Hyena against exactly this operator).

Online-softmax tiling: grid (B, H, q_block, kv_block) with the kv block
innermost; fp32 VMEM scratch carries (m, l, acc) across kv steps.  Causal
and sliding-window masks skip fully-masked kv blocks at the grid level
(pl.when), so wall-clock scales with the *unmasked* area.  GQA is handled in
the kv index_map (kv head = q head // group) — no materialized head repeat.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, blk_q: int, blk_k: int, causal: bool,
    window: int | None, q_offset: int, n_k: int, Lk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions: query rows sit at q_offset + iq*blk_q + a
    q_start = q_offset + iq * blk_q
    k_start = ik * blk_k
    # block-level validity: any key in block <= any query position (causal)
    # and within window
    valid = True
    if causal:
        valid = k_start <= q_start + blk_q - 1
    if window is not None:
        valid = jnp.logical_and(valid, k_start + blk_k - 1 > q_start - window)

    @pl.when(valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (blk_q, Dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (blk_k, Dh)
        v = v_ref[0, 0].astype(jnp.float32)  # (blk_k, Dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (blk_q, blk_k)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = kpos < Lk  # exclude kv padding rows
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]  # (blk_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blk_q", "blk_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, Lq, Dh)
    k: jax.Array,  # (B, Hkv, Lk, Dh)
    v: jax.Array,  # (B, Hkv, Lk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool | None = None,  # None => interpret off-TPU only
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    B, H, Lq, Dh = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    blk_q = min(blk_q, Lq)
    blk_k = min(blk_k, Lk)
    pad_q = (-Lq) % blk_q
    pad_k = (-Lk) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Lqp, Lkp = q.shape[2], k.shape[2]
    n_q, n_k = Lqp // blk_q, Lkp // blk_k
    # decode offset: query row 0 corresponds to absolute position Lk - Lq
    q_offset = Lk - Lq
    grid = (B, H, n_q, n_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, blk_q=blk_q, blk_k=blk_k, causal=causal,
            window=window, q_offset=q_offset, n_k=n_k, Lk=Lk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, blk_k, Dh), lambda b, h, iq, ik: (b, h // G, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, blk_k, Dh), lambda b, h, iq, ik: (b, h // G, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, blk_q, Dh), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Lq]
    return out
