"""Fused RMSNorm Pallas TPU kernel.

Row-blocked over (rows = B·L, D): each grid step loads a (block_rows, D)
tile into VMEM, reduces in fp32 on the VPU, scales, writes back — one HBM
round-trip instead of the 3 reads/1 write of the unfused lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, D)
    g = g_ref[...].astype(jnp.float32)  # (1, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + g)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(
    x: jax.Array,  # (..., D)
    g: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool | None = None,  # None => interpret off-TPU only
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of block_rows
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, g.reshape(1, D))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
