"""End-to-end LM training driver (the paper's §4.2 pipeline): any registered
arch (default: the paper's hyena-153m), byte-level corpus, resumable
sharded data loader, async checkpointing, preemption handling, straggler
monitoring.  This is the single-host entry point; on a real pod the same
step function is lowered by launch/dryrun.py onto the production mesh.

Full-size run (needs a TPU pod):
    python examples/train_lm.py --arch hyena-153m --seq 2048 --batch 256
Container-scale smoke (default): a reduced config, a few hundred steps on
the in-repo corpus.
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_data, tokenizer
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import ft
from repro.train import optim as O
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def build_corpus() -> np.ndarray:
    """Byte corpus from this repository's own sources (offline container)."""
    chunks = []
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    for dirpath, _, files in os.walk(root):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    chunks.append(np.frombuffer(fh.read(), dtype=np.uint8))
    return np.concatenate(chunks).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hyena-153m")
    ap.add_argument("--full", action="store_true",
                    help="use the full (un-reduced) architecture config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(
            cfg.reduced(), d_model=128, n_layers=4,
            vocab_size=tokenizer.VOCAB_SIZE,
        )
    else:
        cfg = dataclasses.replace(cfg, vocab_size=tokenizer.VOCAB_SIZE)

    corpus = build_corpus()
    print(f"corpus: {len(corpus) / 1e6:.1f}M bytes; arch {cfg.name}")
    stream = lm_data.TokenStream(
        corpus, global_batch=args.batch, seq_len=args.seq, seed=0
    )
    prefetch = lm_data.Prefetcher(stream, depth=2)
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(
            lr=args.lr, warmup_steps=min(50, args.steps // 10),
            total_steps=args.steps, weight_decay=0.1,
        ),
        microbatches=args.microbatches,
        remat=True,
    )
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    start = 0
    if ckpt.latest_step(args.ckpt) is not None:
        state, meta, start = ckpt.restore(args.ckpt, state)
        stream.restore(meta["loader"])
        print(f"resumed from step {start}")
    writer = ckpt.AsyncCheckpointer(args.ckpt, keep_last=2)
    handler = ft.PreemptionHandler()
    monitor = ft.StragglerMonitor()
    heartbeat = ft.Heartbeat(os.path.join(args.ckpt, "heartbeat"), 30.0)
    os.makedirs(args.ckpt, exist_ok=True)
    heartbeat.start()
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    tokens_seen = 0
    for i in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in prefetch.next().items()}
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        slow = monitor.record(i, dt)
        tokens_seen += args.batch * args.seq
        if (i + 1) % args.ckpt_every == 0:
            writer.save(i + 1, state, meta={"loader": prefetch.consumed_state})
        if handler.preempted():
            writer.save(i + 1, state, meta={"loader": prefetch.consumed_state})
            writer.close()
            print("preempted — checkpointed, exiting cleanly")
            return
        if i % 20 == 0 or i == args.steps - 1:
            print(
                f"step {i:4d} loss {float(metrics['loss']):.3f} "
                f"gnorm {float(metrics['grad_norm']):.2f} "
                f"{args.batch * args.seq / dt:.0f} tok/s"
                + (" [straggler]" if slow else "")
            )
    writer.save(args.steps, state, meta={"loader": prefetch.consumed_state})
    writer.close()
    heartbeat.stop()
    prefetch.close()
    print(f"done: {tokens_seen / 1e6:.1f}M tokens, stragglers={monitor.stragglers}")
    print("OK")


if __name__ == "__main__":
    main()
