"""End-to-end LM training driver (the paper's §4.2 pipeline): any registered
arch (default: the paper's hyena-153m), byte-level corpus, resumable
sharded data loader — all lifecycle (async checkpointing, preemption
draining, straggler/heartbeat telemetry, resume-from-latest-committed) owned
by the shared ``repro.train.loop.TrainLoop`` (DESIGN.md §10).  This is the
single-host entry point; on a real pod the same step function is lowered by
launch/dryrun.py onto the production mesh.

Full-size run (needs a TPU pod):
    python examples/train_lm.py --arch hyena-153m --seq 2048 --batch 256
Container-scale smoke (default): a reduced config, a few hundred steps on
the in-repo corpus.  Kill and re-run with the same --ckpt to resume
bit-exactly.
"""
import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.data import lm_data, tokenizer
from repro.train import optim as O
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.trainer import TrainConfig


def build_corpus() -> np.ndarray:
    """Byte corpus from this repository's own sources (offline container)."""
    chunks = []
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    for dirpath, _, files in os.walk(root):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    chunks.append(np.frombuffer(fh.read(), dtype=np.uint8))
    return np.concatenate(chunks).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hyena-153m")
    ap.add_argument("--full", action="store_true",
                    help="use the full (un-reduced) architecture config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default=None,
                    choices=["int8_ef"],
                    help="int8 error-feedback compression of the gradient "
                         "all-reduce (cross-pod bandwidth)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(
            cfg.reduced(), d_model=128, n_layers=4,
            vocab_size=tokenizer.VOCAB_SIZE,
        )
    else:
        cfg = dataclasses.replace(cfg, vocab_size=tokenizer.VOCAB_SIZE)

    corpus = build_corpus()
    print(f"corpus: {len(corpus) / 1e6:.1f}M bytes; arch {cfg.name}")
    stream = lm_data.TokenStream(
        corpus, global_batch=args.batch, seq_len=args.seq, seed=0
    )
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(
            lr=args.lr, warmup_steps=min(50, args.steps // 10),
            total_steps=args.steps, weight_decay=0.1,
        ),
        microbatches=args.microbatches,
        remat=True,
        grad_compression=args.grad_compression,
    )
    lcfg = LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every, keep_last=2,
    )
    loop = TrainLoop(cfg, tcfg, lcfg)
    result = loop.run(stream, key=jax.random.PRNGKey(0))
    if result.status == "preempted":
        print("preempted — checkpointed, exiting cleanly")
        return
    tokens_seen = result.step * args.batch * args.seq
    print(f"done: {tokens_seen / 1e6:.1f}M tokens, "
          f"stragglers={result.stragglers}")
    print("OK")


if __name__ == "__main__":
    main()
