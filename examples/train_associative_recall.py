"""Paper §4.1 driver: train a 2-layer Hyena on associative recall and report
accuracy across vocabulary sizes (Fig. 4.1 / Table C.1 protocol, scaled to
this container).  Training runs on the shared resumable loop
(``repro.train.loop.TrainLoop`` — DESIGN.md §10): kill and re-run with the
same --ckpt to continue bit-exactly; pass --compress to train through the
int8 error-feedback gradient channel the multi-pod runs use.

    PYTHONPATH=src python examples/train_associative_recall.py \
        --vocab 20 --seq 64 --steps 80 --ckpt /tmp/recall_ckpt
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import synthetic
from repro.models import lm
from repro.train import optim as O
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.trainer import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("hyena-153m").reduced(),
        vocab_size=max(args.vocab + 2, 16), n_layers=2, d_model=64,
    )
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(
        rng, n=256, seq_len=args.seq, vocab=args.vocab
    )
    test_tokens, test_labels = synthetic.associative_recall(
        rng, n=128, seq_len=args.seq, vocab=args.vocab
    )
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(lr=2e-3, warmup_steps=10,
                                total_steps=args.steps, weight_decay=0.0),
        remat=False,
        grad_compression="int8_ef" if args.compress else None,
    )
    lcfg = LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every, heartbeat_interval=None,
    )
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    loop = TrainLoop(cfg, tcfg, lcfg)
    result = loop.run(lambda step, key: batch, key=jax.random.PRNGKey(0))
    if result.status == "preempted":
        print("preempted — checkpointed, exiting")
        return
    logits, _ = lm.forward(result.state["params"], cfg, jnp.asarray(test_tokens))
    acc = synthetic.eval_accuracy(np.asarray(logits, np.float32), test_labels)
    print(f"vocab={args.vocab} seq={args.seq} test recall accuracy: {acc:.2%}")
    if result.stragglers:
        print("straggler report:", loop.monitor.last_report)
    print("OK")


if __name__ == "__main__":
    main()
