"""Paper §4.1 driver: train a 2-layer Hyena on associative recall and report
accuracy across vocabulary sizes (Fig. 4.1 / Table C.1 protocol, scaled to
this container).  Demonstrates checkpoint/resume fault tolerance: kill and
re-run with the same --ckpt to continue.

    PYTHONPATH=src python examples/train_associative_recall.py \
        --vocab 20 --seq 64 --steps 80 --ckpt /tmp/recall_ckpt
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import synthetic
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import ft
from repro.train import optim as O
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("hyena-153m").reduced(),
        vocab_size=max(args.vocab + 2, 16), n_layers=2, d_model=64,
    )
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(
        rng, n=256, seq_len=args.seq, vocab=args.vocab
    )
    test_tokens, test_labels = synthetic.associative_recall(
        rng, n=128, seq_len=args.seq, vocab=args.vocab
    )
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(lr=2e-3, warmup_steps=10,
                                total_steps=args.steps, weight_decay=0.0),
        remat=False,
    )
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    start = 0
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        state, meta, start = ckpt.restore(args.ckpt, state)
        print(f"resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    monitor = ft.StragglerMonitor()
    handler = ft.PreemptionHandler()
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    for i in range(start, args.steps):
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        monitor.record(i, time.time() - t0)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, i + 1, state)
        if handler.preempted():
            if args.ckpt:
                ckpt.save(args.ckpt, i + 1, state)
            print("preempted — checkpointed, exiting")
            return
        if i % 20 == 0:
            print(f"step {i:3d} loss {float(metrics['loss']):.3f}")
    logits, _ = lm.forward(state["params"], cfg, jnp.asarray(test_tokens))
    acc = synthetic.eval_accuracy(np.asarray(logits, np.float32), test_labels)
    print(f"vocab={args.vocab} seq={args.seq} test recall accuracy: {acc:.2%}")
    if monitor.stragglers:
        print("straggler report:", monitor.last_report)
    print("OK")


if __name__ == "__main__":
    main()
