"""Continuous-batching serving demo: submit prompts with *different*
lengths, horizons, and sampling params to a ``ServeEngine`` slot pool and
stream tokens as they are emitted.

Each request owns its slot only while it is generating — a finished
request's slot is reset and immediately refilled from the admission queue,
so mixed traffic never pays for its slowest member (compare
``benchmarks/bench_serving.py`` against the old padded static batch).

``--mesh DxM`` serves mesh-native (DESIGN.md §9): the slot pool shards by
the rule engine and the decode quantum runs tensor-parallel — force host
devices to try it on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_batched.py --mesh 2x4

``--paged`` swaps in the block-paged engine (DESIGN.md §11): the prompts
below share a common prefix, so the radix prefix cache prefills it once
and later requests fork it copy-on-write — watch the per-request
``prefix_cached_tokens`` in the summary line.

    PYTHONPATH=src python examples/serve_batched.py --arch hyena-153m --paged

Lifecycle guards (DESIGN.md §13): the demo also cancels one request
mid-decode and submits one with a tick ``deadline`` — both finalize with
a structured ``RequestResult`` (``engine.result(rid)``; status one of
completed / failed / deadline_exceeded / cancelled / shed, always
carrying the partial tokens) instead of vanishing or wedging the pool.
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.common.param import split_params
from repro.configs import get_config
from repro.data import tokenizer
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hyena-153m")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a (data, model) debug mesh, e.g. 2x4 "
                    "(needs that many devices)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged engine: block-paged "
                    "caches, radix prefix reuse, chunked prefill")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        vocab_size=tokenizer.VOCAB_SIZE, frontend=None, frontend_len=0,
    )
    params, axes = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    ectx = None
    if args.mesh:
        from repro.distributed.execution import ExecutionContext
        from repro.launch.mesh import parse_mesh_arg

        ectx = ExecutionContext(mesh=parse_mesh_arg(args.mesh))
    prompts = [
        "long convolutions are all you need",
        "long convolutions are not enough",
        "long convolutions beat attention",
        "subquadratic models",
    ]
    max_prompt = max(len(tokenizer.encode(p, add_bos=False)) for p in prompts)
    scfg = ServeConfig(
        max_len=max_prompt + args.new_tokens + 1, n_slots=args.slots,
        temperature=args.temperature, top_k=8,
    )
    if args.paged:
        from repro.serve.paged import PagedConfig, PagedServeEngine

        eng = PagedServeEngine(params, cfg, scfg, PagedConfig(page_size=4),
                               seed=7, ectx=ectx, param_axes=axes)
    else:
        eng = ServeEngine(params, cfg, scfg, seed=7, ectx=ectx,
                          param_axes=axes)

    streamed = {}

    def on_token(rid, token, done):
        streamed.setdefault(rid, []).append(token)

    t0 = time.time()
    rids = {}
    # lifecycle guards (DESIGN.md §13): one request is cancelled
    # mid-decode and one carries a tick deadline it cannot meet — both
    # finalize with a structured RequestResult (partial tokens kept)
    # and release their slot back to the pool immediately
    enc0 = np.asarray(tokenizer.encode(prompts[0], add_bos=False))
    doomed = eng.submit(enc0, max_new_tokens=args.new_tokens,
                        stream=on_token)
    dated = eng.submit(enc0, max_new_tokens=args.new_tokens, deadline=2)
    eng.step()  # both resident now
    eng.cancel(doomed)
    for i, p in enumerate(prompts):
        enc = np.asarray(tokenizer.encode(p, add_bos=False))
        # per-request params: even requests greedy, odd ones sampled
        rids[eng.submit(
            enc, max_new_tokens=args.new_tokens,
            temperature=0.0 if i % 2 == 0 else args.temperature,
            stream=on_token,
        )] = p
    out = eng.drain()
    dt = time.time() - t0

    toks = 0
    for rid, p in rids.items():
        assert streamed[rid] == [int(t) for t in out[rid]]  # stream == drain
        toks += len(out[rid])
        cached = ""
        if args.paged:
            n = eng.request_metrics[rid]["prefix_cached_tokens"]
            cached = f"  [prefix_cached_tokens={n}]"
        print(f"  {p!r} -> {tokenizer.decode(np.asarray(out[rid]))!r}{cached}")
    for rid, why in ((doomed, "cancel()"), (dated, "deadline=2")):
        res = eng.result(rid)
        print(f"  lifecycle[{why}]: status={res.status} after "
              f"{len(res.tokens)} partial tokens")
        assert not res.ok
    print(f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s, "
          f"slots={args.slots}, requests={len(prompts)})")
    print("OK")


if __name__ == "__main__":
    main()
