"""Batched serving demo: prefill a batch of prompts, decode continuations
with the per-mixer caches (Hyena conv-cache / KV ring buffers / SSM state).

    PYTHONPATH=src python examples/serve_batched.py --arch hyena-153m
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import split_params
from repro.configs import get_config
from repro.data import tokenizer
from repro.models import lm
from repro.serve.engine import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hyena-153m")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        vocab_size=tokenizer.VOCAB_SIZE, frontend=None, frontend_len=0,
    )
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    prompts = [
        "attention is all you need",
        "the quick brown fox",
        "hyena operators are",
        "subquadratic models",
    ]
    enc = [tokenizer.encode(p, add_bos=False) for p in prompts]
    width = max(len(e) for e in enc)
    batch = np.stack([np.pad(e, (width - len(e), 0)) for e in enc])

    scfg = ServeConfig(max_len=width + args.new_tokens + 1,
                       temperature=args.temperature, top_k=8)
    t0 = time.time()
    out = generate(
        params, cfg, jnp.asarray(batch), scfg=scfg,
        max_new_tokens=args.new_tokens, key=jax.random.PRNGKey(7),
    )
    dt = time.time() - t0
    toks = out.shape[0] * out.shape[1]
    for p, o in zip(prompts, np.asarray(out)):
        print(f"  {p!r} -> {tokenizer.decode(o)!r}")
    print(f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s, batch={len(prompts)})")
    print("OK")


if __name__ == "__main__":
    main()
