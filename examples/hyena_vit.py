"""Paper §4.5: Hyena as a general operator — image classification with the
attention layers of a ViT replaced by the (unchanged) Hyena operator.
Offline container: synthetic CIFAR-shaped data (two separable classes).

    PYTHONPATH=src python examples/hyena_vit.py [--steps 40]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import split_params
from repro.models.vit import ViTConfig, init_vit, vit_loss
from repro.train import optim as O


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = ViTConfig(image_size=16, patch_size=4, d_model=48, n_layers=2,
                    d_ff=96, n_classes=2)
    params, _ = split_params(init_vit(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(64, 16, 16, 3)).astype(np.float32)
    labels = (imgs[:, :8].mean(axis=(1, 2, 3)) > 0).astype(np.int32)
    imgs[labels == 1, :8] += 0.7  # class-1 brightens the top half
    imgs_j, labels_j = jnp.asarray(imgs), jnp.asarray(labels)

    ocfg = O.AdamWConfig(lr=3e-3, warmup_steps=0, schedule="constant",
                         weight_decay=0.0)
    opt = O.init_adamw(params)

    @jax.jit
    def step(params, opt):
        (loss, m), g = jax.value_and_grad(vit_loss, has_aux=True)(
            params, cfg, imgs_j, labels_j
        )
        params, opt, _ = O.adamw_update(ocfg, g, opt, params)
        return params, opt, loss, m["acc"]

    for i in range(args.steps):
        params, opt, loss, acc = step(params, opt)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss {float(loss):.3f} acc {float(acc):.2f}")
    assert float(acc) > 0.8, "Hyena-ViT failed to fit the synthetic task"
    print("OK")


if __name__ == "__main__":
    main()
