"""Quickstart: build a small Hyena LM, train it briefly on byte-level text,
and sample a continuation.

    PYTHONPATH=src python examples/quickstart.py [--steps 30]

The single static ``generate()`` call below is the simplest serving path.
For concurrent requests with mixed lengths, per-request sampling params,
and per-token streaming callbacks, use the continuous-batching API —
``repro.serve.engine.ServeEngine.submit()/step()/drain()`` — shown in
``examples/serve_batched.py`` (architecture in DESIGN.md §4).  That API
also carries the request lifecycle guards (DESIGN.md §13): ``cancel()``,
tick deadlines, and load shedding, each finalizing a structured
``RequestResult`` (status completed / failed / deadline_exceeded /
cancelled / shed, with partial tokens preserved).
"""
import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_data, tokenizer
from repro.models import lm
from repro.serve.engine import ServeConfig, generate
from repro.train import optim as O
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

TEXT = (
    "the hyena hierarchy is a subquadratic drop-in replacement for attention "
    "built from implicitly parametrized long convolutions and data-controlled "
    "gating. "
) * 400


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("hyena-153m").reduced(),
        vocab_size=tokenizer.VOCAB_SIZE, n_layers=2, d_model=96,
    )
    corpus = tokenizer.encode(TEXT, add_bos=False)
    stream = lm_data.TokenStream(
        corpus, global_batch=16, seq_len=args.seq, seed=0
    )
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(lr=3e-3, warmup_steps=10,
                                total_steps=args.steps, weight_decay=0.01),
        remat=False,
    )
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss {float(metrics['loss']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")

    prompt = tokenizer.encode("the hyena ", add_bos=False)[None, :]
    out = generate(
        state["params"], cfg, jnp.asarray(prompt),
        scfg=ServeConfig(max_len=args.seq + 32, temperature=0.0),
        max_new_tokens=24,
    )
    print("prompt + continuation:", "the hyena " + tokenizer.decode(np.asarray(out[0])))
    print("OK")


if __name__ == "__main__":
    main()
