"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step (grads) + one decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.param import split_params
from repro.configs import get_config
from repro.configs.registry import ASSIGNED
from repro.models import lm

SMOKE_ARCHS = ASSIGNED + ["hyena-153m"]


def _batch(cfg, B=2, L=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    tokens = jax.random.randint(ks[0], (B, L), 0, cfg.vocab_size)
    labels = jax.random.randint(ks[1], (B, L), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None and cfg.frontend_len:
        P = min(cfg.frontend_len, L)
        fe = 0.1 * jax.random.normal(ks[2], (B, P, cfg.d_model), jnp.float32)
        labels = labels.at[:, :P].set(lm.IGNORE)
    return tokens, labels, fe


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    tokens, labels, fe = _batch(cfg)
    logits, _ = lm.forward(params, cfg, tokens, fe)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, tokens, labels, fe), has_aux=True
    )(params)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves), arch
    # at least one non-zero gradient
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), arch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    B = 2
    caches = lm.init_caches(cfg, B, max_len=16, dtype=jnp.float32)
    tok = jnp.zeros((B,), jnp.int32)
    logits, caches2 = lm.decode_step(params, cfg, tok, caches)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # a second step must consume the updated caches without shape drift
    logits2, _ = lm.decode_step(params, cfg, tok + 1, caches2)
    assert np.isfinite(np.asarray(logits2)).all()


def test_hyena_swap_on_attention_arch():
    """The paper's drop-in replacement: attention arch with --mixer hyena."""
    cfg = get_config("phi4-mini-3.8b").reduced().with_mixer("hyena")
    assert cfg.pattern == ("hyena",)
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    tokens, labels, fe = _batch(cfg)
    loss, _ = lm.loss_fn(params, cfg, tokens, labels, fe)
    assert np.isfinite(float(loss))


def test_input_specs_cover_all_cells():
    from repro.configs.shapes import SHAPES, input_specs

    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES:
            specs = input_specs(cfg, shape)
            assert specs, (arch, shape)
