"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import ref
from repro.kernels import rmsnorm as rn
from repro.kernels import short_conv as sc
from repro.kernels import toeplitz_conv as tc


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )


# ------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(4, 8, 32), (2, 128), (1, 3, 5, 64), (300, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32) * 0.1
    got = rn.rmsnorm(x, g, interpret=True, block_rows=64)
    want = ref.rmsnorm(x, g)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


# ---------------------------------------------------------- short conv

@pytest.mark.parametrize("B,L,D,K", [(2, 16, 8, 3), (1, 100, 33, 4), (3, 512, 128, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("gated", [False, True])
def test_short_conv(B, L, D, K, dtype, gated):
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (D, K), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(2), (B, L, D), dtype) if gated else None
    got = sc.short_conv_gate(u, w, g, block_l=64, block_d=32, interpret=True)
    want = ref.short_conv_gate(u, w, g)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


def test_short_conv_causal_blocks():
    """Halo handling: output identical whether L fits one block or many."""
    B, L, D = 1, 256, 16
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, 3), jnp.float32)
    one = sc.short_conv_gate(u, w, block_l=256, block_d=16, interpret=True)
    many = sc.short_conv_gate(u, w, block_l=32, block_d=8, interpret=True)
    np.testing.assert_allclose(one, many, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- toeplitz conv

@pytest.mark.parametrize(
    "B,L,D,C", [(2, 64, 8, 16), (1, 128, 16, 32), (2, 96, 8, 32)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("gated", [False, True])
def test_toeplitz_conv_full(B, L, D, C, dtype, gated):
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D), dtype)
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L), jnp.float32) / L
    skip = jax.random.normal(jax.random.PRNGKey(2), (D,), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(3), (B, L, D), dtype) if gated else None
    got = tc.toeplitz_conv(u, h, skip, g, chunk=C, block_d=8, interpret=True)
    want = ref.toeplitz_conv(u, h, skip, g)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


def test_toeplitz_conv_banded():
    """Banded support matches a filter truncated to K chunk diagonals."""
    B, L, D, C, K = 1, 128, 4, 16, 3
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L), jnp.float32) / L
    got = tc.toeplitz_conv(u, h, chunk=C, block_d=4, n_chunk_diags=K, interpret=True)
    want = ref.toeplitz_conv(u, h, n_chunk_diags=K, chunk=C)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_toeplitz_matches_fftconv():
    """Kernel == core fft path (full support)."""
    from repro.core.fftconv import fft_causal_conv
    B, L, D = 2, 64, 8
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L), jnp.float32) / L
    got = tc.toeplitz_conv(u, h, chunk=16, block_d=8, interpret=True)
    np.testing.assert_allclose(got, fft_causal_conv(u, h), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- flash attention

@pytest.mark.parametrize(
    "B,H,Hkv,L,Dh", [(1, 4, 4, 64, 16), (2, 8, 2, 128, 32), (1, 6, 1, 96, 16)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal(B, H, Hkv, L, Dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, L, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, L, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, L, Dh), dtype)
    got = fa.flash_attention(q, k, v, blk_q=32, blk_k=32, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


def test_flash_window():
    B, H, L, Dh = 1, 2, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(ks[i], (B, H, L, Dh)) for i in range(3))
    got = fa.flash_attention(q, k, v, window=32, blk_q=16, blk_k=16, interpret=True)
    want = ref.flash_attention(q, k, v, window=32)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_decode_shape():
    """Lq=1 decode against a Lk-long KV cache."""
    B, H, Hkv, Lk, Dh = 2, 8, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, 1, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, Lk, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, Lk, Dh))
    got = fa.flash_attention(q, k, v, blk_q=1, blk_k=32, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_unpadded_vs_padded():
    """L not a multiple of the block size (kv padding masked)."""
    B, H, L, Dh = 1, 2, 100, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(ks[i], (B, H, L, Dh)) for i in range(3))
    got = fa.flash_attention(q, k, v, blk_q=32, blk_k=32, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
