"""ExecutionContext substrate tests: mixed-precision policy wiring (train
step + decode quantum), rule-driven state/cache sharding, and the
long-prompt fft_sp routing threshold (DESIGN.md §9)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.param import split_params
from repro.common.policy import BF16, FP32, Policy
from repro.configs import get_config
from repro.distributed.execution import SP_TOKENS_PER_CHIP, ExecutionContext
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine, generate

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _setup(arch="hyena-153m", seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    params, axes = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    return cfg, params, axes


# ------------------------------------------------------------- precision

def test_policy_cast_compute_wired_into_context():
    ctx = ExecutionContext(policy=BF16)
    tree = {"w": jnp.ones((2, 2), jnp.float32), "i": jnp.ones((2,), jnp.int32)}
    cast = ctx.cast_compute(tree)
    assert cast["w"].dtype == jnp.bfloat16  # floats cast
    assert cast["i"].dtype == jnp.int32  # ints untouched
    assert ExecutionContext().cast_compute(tree)["w"].dtype == jnp.float32


def test_train_step_applies_policy():
    """The trainer's mixed precision is live, not advertised: an fp32
    policy and a bf16 policy produce measurably different losses from the
    same fp32 master params (bf16 rounds the params in compute), while the
    master params themselves stay fp32 under both."""
    from repro.train import optim as O
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg, _, _ = _setup()
    cfg = dataclasses.replace(cfg, vocab_size=32, n_layers=2)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 32, (4, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 32, (4, 16)), jnp.int32),
    }
    losses = {}
    for name, pol in (("fp32", FP32), ("bf16", BF16)):
        tcfg = TrainConfig(optimizer=O.AdamWConfig(warmup_steps=0),
                           remat=False, policy=pol)
        st = jax.tree_util.tree_map(lambda x: x, state)
        new_state, metrics = make_train_step(cfg, tcfg)(st, batch)
        losses[name] = float(metrics["loss"])
        for leaf in jax.tree_util.tree_leaves(new_state["params"]):
            assert leaf.dtype == jnp.float32  # masters stay fp32
    assert np.isfinite(losses["fp32"]) and np.isfinite(losses["bf16"])
    assert losses["fp32"] != losses["bf16"]  # the cast actually happened
    assert abs(losses["fp32"] - losses["bf16"]) < 0.1  # ...and is benign


def test_bf16_vs_fp32_decode_smoke():
    """Policy wiring in the decode quantum: an fp32-policy engine is
    token-identical to the fp32 reference ``generate``; a bf16-policy
    engine on the same fp32 caches really serves bf16-cast weights and
    still produces a full, finite token stream."""
    cfg, params, _ = _setup()
    prompt = np.array([3, 5, 7, 2], np.int32)
    scfg32 = ServeConfig(max_len=24, n_slots=2, cache_dtype=jnp.float32)
    eng = ServeEngine(params, cfg, scfg32)
    rid = eng.submit(prompt, max_new_tokens=4)
    out32 = eng.drain()[rid]
    ref = np.asarray(generate(
        params, cfg, jnp.asarray(prompt)[None], scfg=scfg32,
        max_new_tokens=4,
    )[0])
    assert [int(t) for t in out32] == [int(t) for t in ref]

    scfg_bf16 = dataclasses.replace(scfg32, policy=BF16)
    eng_b = ServeEngine(params, cfg, scfg_bf16)
    # the engine holds policy-cast weights (serving never pays fp32 HBM)
    float_leaves = [
        l for l in jax.tree_util.tree_leaves(eng_b.params)
        if jnp.issubdtype(l.dtype, jnp.floating)
    ]
    assert float_leaves and all(l.dtype == jnp.bfloat16 for l in float_leaves)
    rid_b = eng_b.submit(prompt, max_new_tokens=4)
    out_b = eng_b.drain()[rid_b]
    assert len(out_b) == 4
    # bf16 engine matches the bf16-policy reference token-for-token
    ref_b = np.asarray(generate(
        params, cfg, jnp.asarray(prompt)[None], scfg=scfg_bf16,
        max_new_tokens=4,
    )[0])
    assert [int(t) for t in out_b] == [int(t) for t in ref_b]


# ------------------------------------------------------ sharding substrate

def _FakeMesh():
    # AbstractMesh: NamedSharding-compatible without real devices
    from jax.sharding import AbstractMesh

    return AbstractMesh((("data", 2), ("model", 2)))


def test_train_state_shardings_generalize_params_rules():
    """Adam moments mirror the param layout; counters replicate — the
    arbitrary-state-tree generalization of the params-only rule engine."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import train_state_shardings

    axes = {"w": ("embed", "mlp")}
    state = {
        "params": {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)},
        "opt": {
            "m": {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)},
            "v": {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)},
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }
    sh = train_state_shardings(axes, state, _FakeMesh())
    assert sh["params"]["w"].spec == P(None, "model")
    assert sh["opt"]["m"]["w"].spec == P(None, "model")
    assert sh["opt"]["v"]["w"].spec == P(None, "model")
    assert sh["opt"]["step"].spec == P()


def test_tree_shardings_partial_axes_replicate():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import tree_shardings

    values = {
        "a": jax.ShapeDtypeStruct((4, 16), jnp.float32),
        "b": {"c": jax.ShapeDtypeStruct((3,), jnp.int32)},
    }
    sh = tree_shardings({"a": (None, "mlp")}, values, _FakeMesh())
    assert sh["a"].spec == P(None, "model")
    assert sh["b"]["c"].spec == P()  # unannotated subtree replicates
    # structure mismatch (leaf annotation over a subtree) degrades to
    # replication rather than crashing
    sh2 = tree_shardings({"b": ("mlp",)}, values, _FakeMesh())
    assert sh2["b"]["c"].spec == P()
    assert sh2["a"].spec == P()


def test_cache_shardings_rule_driven():
    """lm.cache_shardings resolves every mixer's cache_shard_axes through
    the rule engine: channel dims on 'model', slot dims on 'data',
    cursors and scan-stack dims replicated."""
    from jax.sharding import PartitionSpec as P

    cfg, _, _ = _setup()
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, 2, 16, jnp.float32))
    sh = lm.cache_shardings(cfg, caches, _FakeMesh())
    g0 = sh["groups"][0]
    # stacked hyena "long": (G, N, S, max_len, D) -> slots on data, D on
    # model, operand-history time replicated (kv_seq finds no free axis)
    assert g0["long"].spec == P(None, None, "data", None, "model")
    assert g0["t"].spec == P()  # cursors replicate
    assert g0["short"].spec == P(None, "data", None, "model")


def test_kv_seq_fallback_shards_long_rings():
    """Production GQA regression: 8 KV heads can't divide a 16-way model
    axis, so the batch-1 500K-token KV ring must shard its time dim over
    the leftover data+model axes (the old heuristic's behavior) instead of
    replicating 2 GB/layer per chip; when heads DO divide, they keep the
    model axis (collective-free decode contraction) and the time dim takes
    only the data axes the idle batch dim left behind."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import resolve_spec

    spec = ("cache_slots", "kv_seq", "heads", None)
    shape = (1, 524288, 8, 64)

    class Pod:
        shape = {"data": 16, "model": 16}

    assert resolve_spec(spec, shape, Pod()) == P(None, ("data", "model"))

    class Pod8:
        shape = {"data": 32, "model": 8}

    assert resolve_spec(spec, shape, Pod8()) == P(None, "data", "model")
    # big-batch decode: the batch dim claims the data axes first
    assert resolve_spec(spec, (128, 32768, 8, 64), Pod8()) == P(
        "data", None, "model"
    )


# ----------------------------------------------------- long-prompt routing

def test_sp_threshold_and_routing(monkeypatch):
    """conv_backend_for: fft_sp past the per-mesh threshold (auto =
    SP_TOKENS_PER_CHIP × model size), the configured backend below it,
    0 = disabled; an explicitly configured backend is never silently
    overridden unless sp_min_len opts back in.  Non-divisible L routes
    too — spconv pads to the next multiple internally (DESIGN.md §12)."""
    from repro.distributed.execution import SP_ENV_VAR

    class Mesh8:
        shape = {"model": 8}

    ctx = ExecutionContext(mesh=Mesh8())
    auto = SP_TOKENS_PER_CHIP * 8
    assert ctx.sp_threshold() == auto
    assert ctx.conv_backend_for(auto) == "fft_sp"
    assert ctx.conv_backend_for(auto - 8) is None  # below threshold
    assert ctx.conv_backend_for(auto + 1) == "fft_sp"  # pads internally
    # explicit sp_min_len opts a configured backend into routing
    ctx2 = ExecutionContext(mesh=Mesh8(), sp_min_len=64,
                            conv_backend="blockfft")
    assert ctx2.conv_backend_for(64) == "fft_sp"
    assert ctx2.conv_backend_for(56) == "blockfft"
    # ...but an explicit backend alone (e.g. $REPRO_CONV_BACKEND through
    # the dry-run) is respected at every length
    ctx3 = ExecutionContext(mesh=Mesh8(), conv_backend="blockfft")
    assert ctx3.conv_backend_for(auto) == "blockfft"
    assert ExecutionContext(mesh=Mesh8(), sp_min_len=0).conv_backend_for(
        1 << 20) is None  # routing disabled
    assert ExecutionContext().conv_backend_for(1 << 20) is None  # no mesh
    # env override of the auto threshold (explicit field still wins)
    monkeypatch.setenv(SP_ENV_VAR, "128")
    assert ExecutionContext(mesh=Mesh8()).sp_threshold() == 128
    assert ExecutionContext(mesh=Mesh8(), sp_min_len=64).sp_threshold() == 64
    monkeypatch.setenv(SP_ENV_VAR, "0")
    assert ExecutionContext(mesh=Mesh8()).sp_threshold() is None
    monkeypatch.delenv(SP_ENV_VAR)

    class NoModel:
        shape = {"data": 8}

    assert ExecutionContext(mesh=NoModel()).sp_threshold() is None


def test_fft_sp_prefill_routing_end_to_end():
    """A hyena prefill whose L crosses the threshold really runs through
    the sequence-parallel conv — and its logits match the default fft
    path (8 forced host devices, subprocess)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.common.param import split_params
        from repro.configs import get_config
        from repro.distributed import ctx as dctx
        from repro.distributed.execution import ExecutionContext
        from repro.models import lm

        cfg = get_config("hyena-153m").reduced()
        cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
        params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                    cfg.vocab_size)
        mesh = jax.make_mesh((8,), ("model",))
        routed = ExecutionContext(mesh=mesh, sp_min_len=16)
        assert routed.conv_backend_for(16) == "fft_sp"
        lg1, _ = lm.prefill(params, cfg, prompt, 24, dtype=jnp.float32,
                            compute_dtype=jnp.float32)
        with dctx.use_mesh(mesh):
            lg2, _ = lm.prefill(params, cfg, prompt, 24, dtype=jnp.float32,
                                compute_dtype=jnp.float32, ctx=routed)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    assert "OK" in proc.stdout
