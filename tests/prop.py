"""Minimal property-based testing shim (hypothesis is not installed in this
offline container).  Same idea: seeded strategies + a ``@given`` decorator
running N examples and reporting the failing seed for reproduction.
"""
from __future__ import annotations

import functools
import os
from typing import Callable

import numpy as np

N_EXAMPLES = int(os.environ.get("PROP_EXAMPLES", "25"))


class Strategy:
    def __init__(self, fn: Callable[[np.random.Generator], object]):
        self.fn = fn

    def sample(self, rng):
        return self.fn(rng)


def integers(lo: int, hi: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def floats(lo: float, hi: float) -> Strategy:
    return Strategy(lambda rng: float(rng.uniform(lo, hi)))


def sampled_from(options) -> Strategy:
    opts = list(options)
    return Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])


def arrays(shape_strategy, lo=-2.0, hi=2.0, dtype=np.float32) -> Strategy:
    def gen(rng):
        shape = shape_strategy.sample(rng) if isinstance(shape_strategy, Strategy) else shape_strategy
        return rng.uniform(lo, hi, size=shape).astype(dtype)

    return Strategy(gen)


def given(**strategies):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the strategy parameters (it would look for fixtures).
        def wrapper():
            for ex in range(N_EXAMPLES):
                rng = np.random.default_rng(1000 * ex + 7)
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"property failed on example {ex} with {drawn!r}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
