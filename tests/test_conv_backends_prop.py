"""Cross-backend property tests: every registered ConvBackend computes the
same depthwise causal convolution, within dtype tolerance, on random
``(B, L, D)`` — including non-power-of-two and prime ``L`` (the FFT-family
backends pad to a fast composite >= 2L-1 internally; blockfft additionally
factors that length for the four-step transform, so odd/prime lengths
exercise its worst-case path).

The oracle is the O(L²) materialized Toeplitz matmul ("direct").

Gated parity (DESIGN.md §7): for every backend, the fused gated entry point
``backend(u, h, skip, gate)`` must equal the two-pass schedule
``gate * backend(u, h, skip)`` — including the padded/tail-block edges of
the Pallas kernels, which see the gate through an extra BlockSpec and must
not gate the padding rows into the live output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import prop
from repro.core.conv_api import get_conv_backend, registered_conv_backends

# primes, odd composites, powers of two, and off-by-one straddles
LENGTHS = (1, 2, 3, 5, 7, 13, 16, 31, 33, 37, 48, 61, 64, 97, 127, 128)


def _run_all_backends(B, L, D, seed, with_skip, with_gate=False,
                      dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    # bf16 inputs round identically for every backend, but each backend
    # reassociates its fp32 internals differently before the downcast
    tol = 5e-3 if dtype == jnp.float32 else 4e-2
    u = jnp.asarray(rng.standard_normal((B, L, D)), dtype)
    h = jnp.asarray(rng.standard_normal((D, L)) / max(L, 1), jnp.float32)
    skip = (
        jnp.asarray(rng.standard_normal((D,)), jnp.float32)
        if with_skip else None
    )
    gate = (
        jnp.asarray(rng.standard_normal((B, L, D)), dtype)
        if with_gate else None
    )
    want = np.asarray(get_conv_backend("direct")(u, h, skip, gate),
                      np.float32)
    for name, backend in sorted(registered_conv_backends().items()):
        if backend.max_len and L > backend.max_len:
            continue
        got = np.asarray(backend(u, h, skip, gate), np.float32)
        np.testing.assert_allclose(
            got, want, rtol=tol, atol=tol,
            err_msg=f"backend '{name}' diverges at (B={B}, L={L}, D={D}, "
            f"seed={seed}, skip={with_skip}, gate={with_gate}, "
            f"dtype={jnp.dtype(dtype).name})",
        )
        if with_gate:
            # fused == gate * unfused, per backend (not just vs the oracle)
            two_pass = np.asarray(gate * backend(u, h, skip), np.float32)
            np.testing.assert_allclose(
                got, two_pass, rtol=tol, atol=tol,
                err_msg=f"backend '{name}' gated fusion diverges from its "
                f"own two-pass schedule at (B={B}, L={L}, D={D}, "
                f"seed={seed}, skip={with_skip}, "
                f"dtype={jnp.dtype(dtype).name})",
            )


@prop.given(
    B=prop.integers(1, 3),
    L=prop.sampled_from(LENGTHS),
    D=prop.sampled_from((1, 2, 4, 5)),
    seed=prop.integers(0, 1 << 30),
    with_skip=prop.sampled_from((True, False)),
)
def test_conv_backends_agree_random_shapes(B, L, D, seed, with_skip):
    _run_all_backends(B, L, D, seed, with_skip)


test_conv_backends_agree_random_shapes = pytest.mark.slow(
    test_conv_backends_agree_random_shapes
)


@prop.given(
    B=prop.integers(1, 3),
    L=prop.sampled_from(LENGTHS),
    D=prop.sampled_from((1, 2, 4, 5)),
    seed=prop.integers(0, 1 << 30),
    with_skip=prop.sampled_from((True, False)),
)
def test_conv_backends_gated_parity(B, L, D, seed, with_skip):
    _run_all_backends(B, L, D, seed, with_skip, with_gate=True)


test_conv_backends_gated_parity = pytest.mark.slow(
    test_conv_backends_gated_parity
)


@pytest.mark.parametrize("L", [7, 37, 61, 97])
def test_conv_backends_agree_prime_lengths(L):
    """Fast-tier pin on the prime lengths (the historically risky cases for
    padded-FFT and factored-FFT implementations)."""
    _run_all_backends(2, L, 4, seed=L, with_skip=True)


@pytest.mark.parametrize("L", [7, 33, 61, 128])
def test_conv_backends_gated_parity_fast(L):
    """Fast-tier pin of the gated-parity property (odd, straddle, prime,
    and exact-block lengths)."""
    _run_all_backends(2, L, 4, seed=1000 + L, with_skip=True, with_gate=True)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("L", [13, 37, 100])
def test_conv_backends_gated_parity_dtypes(L, dtype):
    """Gated-parity grid across dtypes × odd/prime lengths (D=5 so every
    tiled backend sees a padded channel tail).  The bf16 rows pin the §7
    downcast-then-gate policy for every backend, including the two-level
    overlapped registration."""
    _run_all_backends(2, L, 5, seed=31 * L, with_skip=True, with_gate=True,
                      dtype=getattr(jnp, dtype))


def test_fft_sp_registered_with_contract():
    """The sequence-parallel conv is a first-class registry citizen: mesh
    aware, gate fused inside the shard_map epilogue (bit-identical to the
    unfused registry fallback — DESIGN.md §7/§12), and — with no ambient
    mesh — included in every sweep above via its local-FFT fallback."""
    from repro.core.conv_api import get_conv_backend

    b = get_conv_backend("fft_sp")
    assert b.mesh_aware and b.supports_gate and not b.oracle


def test_fft_sp_sharded_gated_parity_subprocess():
    """fft_sp on a REAL 8-way model mesh (subprocess, forced host devices):
    the sharded two-stage Cooley-Tukey path — not the fallback — must match
    the fft backend, gated and ungated, including an odd batch and a skip.
    This is the mesh half of the registry parity sweep (the in-process
    sweep only ever sees the meshless fallback)."""
    import os
    import subprocess
    import sys
    import textwrap

    SRC = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.conv_api import get_conv_backend
        from repro.distributed import ctx

        mesh = jax.make_mesh((8,), ("model",))
        fft_sp = get_conv_backend("fft_sp")
        fft = get_conv_backend("fft_local")
        B, L, D = 3, 64, 4
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
        h = jnp.asarray(rng.standard_normal((D, L)) / L, jnp.float32)
        skip = jnp.asarray(rng.standard_normal((D,)), jnp.float32)
        gate = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
        with ctx.use_mesh(mesh):
            got = np.asarray(fft_sp(u, h, skip, gate))
            got_plain = np.asarray(fft_sp(u, h, skip))
        want = np.asarray(fft(u, h, skip, gate))
        want_plain = np.asarray(fft(u, h, skip))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(got_plain, want_plain,
                                   rtol=2e-3, atol=2e-3)
        # L % 8 != 0 must fall back, not crash
        u2, h2 = u[:, :61], h[:, :61] * 0.0 + h[:, :61]
        with ctx.use_mesh(mesh):
            np.asarray(fft_sp(u2, h2, skip))
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    assert "OK" in proc.stdout


@pytest.mark.parametrize(
    "B,L,D,C,bd",
    [(2, 100, 33, 32, 32), (1, 96, 8, 32, 8), (2, 65, 5, 16, 4)],
)
def test_toeplitz_pallas_gated_tail_blocks(B, L, D, C, bd):
    """The gated Pallas kernel body (interpret mode) on shapes whose L / D
    pad up to the tile grid: the gate BlockSpec must track the output chunk
    through the padded tail blocks."""
    from repro.kernels import ref
    from repro.kernels.toeplitz_conv import toeplitz_conv

    rng = np.random.default_rng(L * 31 + D)
    u = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((D, L)) / L, jnp.float32)
    skip = jnp.asarray(rng.standard_normal((D,)), jnp.float32)
    gate = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    got = toeplitz_conv(
        u, h, skip, gate, chunk=C, block_d=bd, interpret=True
    )
    want = ref.toeplitz_conv(u, h, skip, gate)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_blockfft_overlap_registered_with_contract():
    """The overlapped two-level conv is a first-class registry citizen:
    gate fused at the kernel's finalize (DESIGN.md §14), never the oracle,
    and requires_pallas=False — off-TPU it degrades to the identical
    blockfft math, so every CPU sweep above exercises the real registered
    entry point."""
    b = get_conv_backend("blockfft_overlap")
    assert b.supports_gate and not b.oracle and not b.requires_pallas
    assert b.tag == "twolevel_overlap"


@pytest.mark.parametrize(
    "B,L,D,bd,ov",
    [(2, 100, 5, 4, 2), (1, 37, 3, 2, 4), (2, 64, 4, 4, 2)],
)
def test_twolevel_pallas_gated_tail_blocks(B, L, D, bd, ov):
    """The overlapped two-level kernel BODY (interpret mode, not the CPU
    degrade path) on shapes whose D pads up to the channel tile: the
    spectrum accumulation across overlap chunks, the VMEM finalize, and
    the gate/skip BlockSpecs must all track the padded tail blocks."""
    from repro.core.blockfft import factor_candidates
    from repro.core.fftconv import next_fast_len
    from repro.kernels.twolevel_fft import twolevel_fft_conv

    N = next_fast_len(2 * L - 1)
    factors = factor_candidates(N, limit=2)[0]
    rng = np.random.default_rng(L * 7 + D)
    u = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((D, L)) / L, jnp.float32)
    skip = jnp.asarray(rng.standard_normal((D,)), jnp.float32)
    gate = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    want = np.asarray(get_conv_backend("direct")(u, h, skip, gate))
    got = twolevel_fft_conv(
        u, h, skip, gate, factors=factors, block_d=bd, overlap=ov,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got), want, rtol=2e-4, atol=2e-4,
        err_msg=f"gated twolevel kernel (factors={factors})",
    )
    # ungated + skipless: the dummy gate row / zero skip paths
    want0 = np.asarray(get_conv_backend("direct")(u, h, None, None))
    got0 = twolevel_fft_conv(
        u, h, None, None, factors=factors, block_d=bd, overlap=ov,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got0), want0, rtol=2e-4, atol=2e-4,
        err_msg=f"ungated twolevel kernel (factors={factors})",
    )
