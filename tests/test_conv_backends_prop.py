"""Cross-backend property test: every registered ConvBackend computes the
same depthwise causal convolution, within dtype tolerance, on random
``(B, L, D)`` — including non-power-of-two and prime ``L`` (the FFT-family
backends pad to 2L internally; blockfft additionally factors 2L for the
four-step transform, so odd/prime lengths exercise its worst-case path).

The oracle is the O(L²) materialized Toeplitz matmul ("direct").
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import prop
from repro.core.conv_api import get_conv_backend, registered_conv_backends

# primes, odd composites, powers of two, and off-by-one straddles
LENGTHS = (1, 2, 3, 5, 7, 13, 16, 31, 33, 37, 48, 61, 64, 97, 127, 128)


def _run_all_backends(B, L, D, seed, with_skip):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((D, L)) / max(L, 1), jnp.float32)
    skip = (
        jnp.asarray(rng.standard_normal((D,)), jnp.float32)
        if with_skip else None
    )
    want = np.asarray(get_conv_backend("direct")(u, h, skip))
    for name, backend in sorted(registered_conv_backends().items()):
        if backend.max_len and L > backend.max_len:
            continue
        got = np.asarray(backend(u, h, skip))
        np.testing.assert_allclose(
            got, want, rtol=5e-3, atol=5e-3,
            err_msg=f"backend '{name}' diverges at (B={B}, L={L}, D={D}, "
            f"seed={seed}, skip={with_skip})",
        )


@prop.given(
    B=prop.integers(1, 3),
    L=prop.sampled_from(LENGTHS),
    D=prop.sampled_from((1, 2, 4, 5)),
    seed=prop.integers(0, 1 << 30),
    with_skip=prop.sampled_from((True, False)),
)
def test_conv_backends_agree_random_shapes(B, L, D, seed, with_skip):
    _run_all_backends(B, L, D, seed, with_skip)


test_conv_backends_agree_random_shapes = pytest.mark.slow(
    test_conv_backends_agree_random_shapes
)


@pytest.mark.parametrize("L", [7, 37, 61, 97])
def test_conv_backends_agree_prime_lengths(L):
    """Fast-tier pin on the prime lengths (the historically risky cases for
    padded-FFT and factored-FFT implementations)."""
    _run_all_backends(2, L, 4, seed=L, with_skip=True)
