"""Distributed-runtime tests.  Multi-device cases run in subprocesses with
``--xla_force_host_platform_device_count=8`` so the main test process (and
the smoke tests) keep seeing exactly 1 device."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


# -------------------------------------------------------- sharding rules

def test_param_sharding_rules_divisibility():
    """40 experts / 40 heads don't divide 16 → replicated fallback; mlp &
    vocab shard; fsdp puts embed dims on data axes."""
    from repro.distributed.sharding import resolve_spec
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # mesh sizes are 1 here, so craft a fake mesh-shape via a real mesh of
    # the production shape is impossible in-process; use the rule engine's
    # divisibility math directly with a mocked mesh-shape mapping.
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    assert resolve_spec(("embed", "mlp"), (1024, 13824), FakeMesh()) == P(None, "model")
    assert resolve_spec(("experts", "embed", "expert_ff"), (40, 1536, 512), FakeMesh()) == P(None, None, "model")
    assert resolve_spec(("experts", "embed", "expert_ff"), (16, 6144, 10752), FakeMesh()) == P("model")
    assert resolve_spec(("vocab", "embed"), (92553, 2048), FakeMesh()) == P()
    assert resolve_spec(("vocab", "embed"), (152064, 8192), FakeMesh(),
                        fsdp=True) == P("model", "data")
    # stacked-layer leading dim is replicated
    assert resolve_spec(("embed", "mlp"), (18, 768, 1536), FakeMesh(),
                        extra_leading=1) == P(None, None, "model")


def test_shard_constraint_noop_without_mesh():
    from repro.distributed.ctx import shard

    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(shard(x, "data", None), x)


def test_data_alias_expands_to_pod():
    from repro.distributed.ctx import _filter_spec
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = _filter_spec(FakeMesh(), (256, 128), ("data", None))
    assert spec == P(("pod", "data"), None)


# ------------------------------------------------- multi-device (subproc)

def test_sharded_train_step_matches_single_device():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed import ctx
        from repro.distributed.sharding import param_shardings
        from repro.common.param import split_params
        from repro.models import lm
        from repro.train import optim as O
        from repro.train.trainer import TrainConfig, init_train_state, make_train_step

        cfg = get_config("hyena-153m").reduced()
        cfg = dataclasses.replace(cfg, vocab_size=64, n_layers=2)
        tcfg = TrainConfig(optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
                           remat=False)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)
        labels = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 64)
        batch = {"tokens": tokens, "labels": labels}

        # single device
        state, axes = init_train_state(jax.random.PRNGKey(0), cfg)
        s1, m1 = make_train_step(cfg, tcfg)(state, batch)

        # 2x4 mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pshard = param_shardings(axes, state["params"], mesh, fsdp=True)
        state2, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        state2 = {
            "params": jax.device_put(state2["params"], pshard),
            "opt": {
                "m": jax.device_put(state2["opt"]["m"], pshard),
                "v": jax.device_put(state2["opt"]["v"], pshard),
                "step": jax.device_put(state2["opt"]["step"],
                                       NamedSharding(mesh, P())),
            },
        }
        bshard = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
                  for k, v in batch.items()}
        with ctx.use_mesh(mesh):
            s2, m2 = jax.jit(make_train_step(cfg, tcfg))(state2, bshard)
        print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
        lr = 1e-3
        for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            d = np.abs(np.asarray(a, np.float32) - np.asarray(jax.device_get(b), np.float32))
            scale = max(np.abs(np.asarray(a, np.float32)).max(), 1e-3)
            # Adam step 1 moves every param by exactly +-lr*(1+eps'); two
            # topologies may disagree by 2*lr where bf16 noise flips the
            # gradient sign near zero. Anything beyond that is a real bug.
            assert d.max() <= 2.2 * lr + 5e-2 * scale, d.max()
        print("OK")
    """)
    assert "OK" in out


def test_sp_fft_conv_matches_reference():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fftconv import fft_causal_conv
        from repro.distributed.spconv import sp_fft_causal_conv

        mesh = jax.make_mesh((8,), ("model",))
        B, L, D = 2, 64, 4
        u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
        h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
        skip = jax.random.normal(jax.random.PRNGKey(2), (D,))
        want = fft_causal_conv(u, h, skip)
        got = sp_fft_causal_conv(u, h, skip, mesh, axis="model")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_matches_sequential():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward

        S, T, mb, d = 4, 6, 3, 8
        mesh = jax.make_mesh((4,), ("pipe",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) / np.sqrt(d)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, mb, d))

        def stage(w, h):
            return jnp.tanh(h @ w)

        got = pipeline_forward(stage, ws, x, mesh, axis="pipe")
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        # out_specs=P() declares the result replicated: that must be TRUE
        # on every device, not just on stage 0 (zeros elsewhere used to be
        # masked by check=False and whichever shard assembled the global
        # array).  Check the per-device replicas.
        shards = [np.asarray(s.data) for s in got.addressable_shards]
        assert len(shards) == 4
        for sh in shards:
            np.testing.assert_allclose(sh, np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_accuracy():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.distributed.ctx import shard_map

        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def body(xb):
            return compressed_psum(xb, "data")

        fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                       out_specs=P("data"))
        got = fn(x)[0]
        want = jnp.sum(x, axis=0)
        err = np.abs(np.asarray(got) - np.asarray(want)).max()
        amax = np.abs(np.asarray(x)).max()
        assert err <= 8 * (amax / 127.0) + 1e-6, err  # <= n_shards * 1 ulp
        print("OK")
    """)
    assert "OK" in out


def test_compressed_train_step_on_mesh():
    """grad_compression='int8_ef' lowers and runs under SPMD: the sharded
    compressed step tracks the single-device compressed step (residuals
    and all), to all-reduce-order tolerance."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.distributed import ctx
        from repro.train import optim as O
        from repro.train.trainer import TrainConfig, init_train_state, make_train_step

        cfg = get_config("hyena-153m").reduced()
        cfg = dataclasses.replace(cfg, vocab_size=64, n_layers=2)
        tcfg = TrainConfig(optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
                           remat=False, grad_compression="int8_ef")
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)
        labels = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 64)
        batch = {"tokens": tokens, "labels": labels}

        state, axes = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        assert "cgrad" in state
        s1, m1 = make_train_step(cfg, tcfg)(state, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ectx = tcfg.apply_context(mesh=mesh)
        state2, _ = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        shardings = ectx.train_state_shardings(axes, state2)
        # the rule engine places the residuals exactly like the params
        for ps, cs in zip(jax.tree_util.tree_leaves(shardings["params"]),
                          jax.tree_util.tree_leaves(shardings["cgrad"])):
            assert ps.spec == cs.spec, (ps, cs)
        state2 = jax.device_put(state2, shardings)
        bshard = {k: jax.device_put(v, ectx.data_sharding(v.ndim, v.shape[0]))
                  for k, v in batch.items()}
        with ctx.use_mesh(mesh):
            s2, m2 = jax.jit(make_train_step(cfg, tcfg))(state2, bshard)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
        assert float(m2["compression_abs_err"]) > 0
        lr = 1e-3
        for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            a = np.asarray(a, np.float32)
            b = np.asarray(jax.device_get(b), np.float32)
            scale = max(np.abs(a).max(), 1e-3)
            # same bound as the uncompressed cross-topology test: Adam step
            # 1 is +-lr per element; quantization + reduce-order noise can
            # flip signs near zero but never exceed the 2*lr envelope
            assert np.abs(a - b).max() <= 2.2 * lr + 5e-2 * scale
        # residuals are carried (nonzero) and bounded by one quantization
        # bucket of their gradient leaf on both topologies
        r2 = max(np.abs(np.asarray(jax.device_get(x), np.float32)).max()
                 for x in jax.tree_util.tree_leaves(s2["cgrad"]))
        assert 0 < r2 < 1.0, r2
        print("OK")
    """)
    assert "OK" in out


# ------------------------------------------------------ error feedback

def test_error_feedback_contracts():
    """Residual stays bounded and compressed grads average to the truth."""
    from repro.distributed import compression as C

    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    r = C.init_residuals(g)
    acc = jnp.zeros((64,))
    for _ in range(50):
        out, r, diag = C.compress_decompress_with_feedback(g, r)
        acc = acc + out["w"]
    # mean of compressed equals true gradient to quantization precision
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g["w"]),
                               atol=np.abs(g["w"]).max() / 127.0 * 2)


def test_quantize_roundtrip_property():
    import prop
    from repro.distributed import compression as C

    @prop.given(scale=prop.floats(0.01, 100.0))
    def check(scale):
        x = jnp.asarray(
            np.random.default_rng(3).normal(size=(128,)) * scale, jnp.float32
        )
        q, s = C.quantize_int8(x)
        err = np.abs(np.asarray(C.dequantize_int8(q, s)) - np.asarray(x))
        assert err.max() <= float(s) * 0.5 + 1e-7

    check()
