"""Core Hyena operator algebra tests (paper Def 3.1, §3.2, Prop 3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.param import split_params
from repro.core import (
    FilterConfig,
    HyenaConfig,
    conv_cache_step,
    direct_causal_conv,
    evaluate_filters,
    fft_causal_conv,
    hyena_decode_step,
    hyena_operator,
    init_decode_cache,
    init_hyena,
    precompute_decode_filters,
)
from repro.core.matrices import apply_H, toeplitz


def make_op(key, D=16, order=2, L=None):
    cfg = HyenaConfig(
        d_model=D,
        order=order,
        filter=FilterConfig(d_model=D, order=order, ffn_width=16, pos_dim=9),
    )
    params, _ = split_params(init_hyena(key, cfg))
    return cfg, params


# ---------------------------------------------------------------- fftconv

@pytest.mark.parametrize("L", [1, 2, 8, 33, 128])
@pytest.mark.parametrize("D", [1, 5])
def test_fft_conv_matches_direct(L, D):
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (2, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L))
    skip = jax.random.normal(jax.random.PRNGKey(2), (D,))
    np.testing.assert_allclose(
        fft_causal_conv(u, h, skip), direct_causal_conv(u, h, skip),
        rtol=1e-4, atol=1e-4,
    )


def test_fft_conv_is_causal():
    """Perturbing u at position t never changes y before t."""
    L, D = 32, 4
    u = jax.random.normal(jax.random.PRNGKey(0), (1, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L))
    y0 = fft_causal_conv(u, h)
    t = 17
    u2 = u.at[:, t:].add(jax.random.normal(jax.random.PRNGKey(2), (1, L - t, D)))
    y1 = fft_causal_conv(u2, h)
    np.testing.assert_allclose(y0[:, :t], y1[:, :t], rtol=1e-5, atol=1e-5)
    assert not np.allclose(y0[:, t:], y1[:, t:])


def test_toeplitz_matrix():
    h = jnp.arange(4.0)
    S = toeplitz(h)
    expect = np.array(
        [[0, 0, 0, 0], [1, 0, 0, 0], [2, 1, 0, 0], [3, 2, 1, 0]], dtype=np.float32
    )
    np.testing.assert_allclose(S, expect)


# ------------------------------------------------------------- operator

@pytest.mark.parametrize("order", [1, 2, 3])
def test_recurrence_matches_matrix_form(order):
    """y = H(u) v with H = D_x^N S^N ... D_x^1 S^1 (paper §3.2)."""
    key = jax.random.PRNGKey(42)
    cfg, params = make_op(key, D=8, order=order)
    u = jax.random.normal(jax.random.PRNGKey(7), (2, 24, 8))
    y_fast = hyena_operator(params, cfg, u)
    y_mat = apply_H(params, cfg, u)
    np.testing.assert_allclose(y_fast, y_mat, rtol=2e-3, atol=2e-3)


def test_operator_causality():
    cfg, params = make_op(jax.random.PRNGKey(0), D=8, order=2)
    L = 40
    u = jax.random.normal(jax.random.PRNGKey(1), (1, L, 8))
    y0 = hyena_operator(params, cfg, u)
    t = 23
    u2 = u.at[:, t:].set(0.0)
    y1 = hyena_operator(params, cfg, u2)
    np.testing.assert_allclose(y0[:, :t], y1[:, :t], rtol=1e-4, atol=1e-4)


def test_operator_linear_in_v_given_gates():
    """H(u) is linear in v: doubling v (via the value pathway) doubles y
    when gates are held fixed — checked through the materialized matrix."""
    from repro.core.matrices import materialize_H
    cfg, params = make_op(jax.random.PRNGKey(0), D=4, order=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 4))
    H = materialize_H(params, cfg, u)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 4))
    y1 = jnp.einsum("bdlk,bkd->bld", H, v)
    y2 = jnp.einsum("bdlk,bkd->bld", H, 2.0 * v)
    np.testing.assert_allclose(2.0 * y1, y2, rtol=1e-5)


def test_backends_agree():
    """The conv backend is an execution option (ApplyContext / conv_api
    registry), not part of the operator's parameter config."""
    cfg, params = make_op(jax.random.PRNGKey(3), D=8, order=2)
    u = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 8))
    np.testing.assert_allclose(
        hyena_operator(params, cfg, u, conv_backend="fft"),
        hyena_operator(params, cfg, u, conv_backend="direct"),
        rtol=1e-4, atol=1e-4,
    )


def test_unknown_backend_raises_before_tracing():
    cfg, params = make_op(jax.random.PRNGKey(3), D=8, order=2)
    u = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 8))
    with pytest.raises(ValueError, match="registered"):
        hyena_operator(params, cfg, u, conv_backend="cufft")


def test_filters_shape_and_grad():
    cfg = FilterConfig(d_model=8, order=3, ffn_width=16, pos_dim=9)
    from repro.core.filters import init_hyena_filter
    params, _ = split_params(init_hyena_filter(jax.random.PRNGKey(0), cfg))
    h = evaluate_filters(params, cfg, 64)
    assert h.shape == (3, 8, 64)
    assert np.isfinite(np.asarray(h)).all()

    def loss(p):
        return jnp.sum(evaluate_filters(p, cfg, 64) ** 2)

    g = jax.grad(loss)(params)
    flat = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)
    assert any(float(jnp.abs(x).max()) > 0 for x in flat)


# --------------------------------------------------------------- decode

def test_conv_cache_step_reference_matches_direct():
    """conv_cache_step is the single-order reference semantics the stacked
    decode dot in hyena_decode_step must reproduce — pin it against the
    teacher-forced conv so the exported reference cannot rot."""
    B, L, D = 2, 10, 4
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
    skip = jax.random.normal(jax.random.PRNGKey(2), (D,))
    want = direct_causal_conv(u, h, skip)
    cache = jnp.zeros((B, L, D))
    for t in range(L):
        y_t, cache = conv_cache_step(cache, u[:, t], h, skip)
        np.testing.assert_allclose(
            y_t, want[:, t], rtol=1e-5, atol=1e-5, err_msg=f"step {t}"
        )


def test_decode_matches_prefill():
    """Token-by-token decode reproduces the teacher-forced forward pass."""
    D, L, B = 8, 12, 2
    cfg, params = make_op(jax.random.PRNGKey(0), D=D, order=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, L, D))
    y_ref = hyena_operator(params, cfg, u)

    cache = init_decode_cache(cfg, B, max_len=L, dtype=jnp.float32)
    cache = precompute_decode_filters(params, cfg, L, cache)
    ys = []
    for t in range(L):
        y_t, cache = hyena_decode_step(params, cfg, u[:, t], cache)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_ref, rtol=5e-3, atol=5e-3)


def test_decode_without_precompute_evaluates_filters_once(monkeypatch):
    """The forgot-precompute fallback is one-time-cached: the filter FFN
    must NOT be re-evaluated on every decode token (the serving-latency
    cliff the taps memo exists to prevent)."""
    from repro.core import filters as F
    from repro.core import operator as op

    D, L, B = 8, 10, 2
    cfg, params = make_op(jax.random.PRNGKey(0), D=D, order=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, L, D))
    y_ref = hyena_operator(params, cfg, u)

    calls = {"n": 0}
    real = F.evaluate_filters

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(op.F, "evaluate_filters", counting)
    cache = init_decode_cache(cfg, B, max_len=L, dtype=jnp.float32)  # no taps
    ys = []
    for t in range(L):
        y_t, cache = hyena_decode_step(params, cfg, u[:, t], cache)
        ys.append(y_t)
    assert calls["n"] == 1, f"filter FFN evaluated {calls['n']}x for {L} tokens"
    np.testing.assert_allclose(
        jnp.stack(ys, axis=1), y_ref, rtol=5e-3, atol=5e-3
    )
