"""Block-FFT conv (beyond-paper MXU path) and Hyena-ViT tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blockfft import blockfft_causal_conv, _factor
from repro.core.fftconv import fft_causal_conv


@pytest.mark.parametrize("L", [8, 32, 128, 512])
@pytest.mark.parametrize("D", [1, 6])
def test_blockfft_matches_fft(L, D):
    u = jax.random.normal(jax.random.PRNGKey(0), (2, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
    skip = jax.random.normal(jax.random.PRNGKey(2), (D,))
    got = blockfft_causal_conv(u, h, skip)
    want = fft_causal_conv(u, h, skip)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_blockfft_in_hyena_mixer():
    from repro.common.param import split_params
    from repro.core import HyenaConfig, FilterConfig
    from repro.core.operator import init_hyena
    from repro.models.hyena import apply_hyena_mixer
    from repro.models.mixer_api import ApplyContext

    cfg = HyenaConfig(
        d_model=16, order=2,
        filter=FilterConfig(d_model=16, order=2, ffn_width=16, pos_dim=9),
    )
    params, _ = split_params(init_hyena(jax.random.PRNGKey(0), cfg))
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y_fft = apply_hyena_mixer(params, cfg, u, ApplyContext(conv_backend="fft"))
    y_bl = apply_hyena_mixer(
        params, cfg, u, ApplyContext(conv_backend="blockfft")
    )
    np.testing.assert_allclose(y_fft, y_bl, rtol=2e-3, atol=2e-3)


def test_factorization():
    for N in [16, 64, 1024, 65536]:
        R, S = _factor(N)
        assert R * S == N and R >= S


def test_vit_forward_and_grad():
    from repro.common.param import split_params
    from repro.models.vit import ViTConfig, apply_vit, init_vit, vit_loss

    cfg = ViTConfig(image_size=16, patch_size=4, d_model=32, n_layers=2,
                    d_ff=64, n_classes=10)
    params, _ = split_params(init_vit(jax.random.PRNGKey(0), cfg))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    logits = apply_vit(params, cfg, imgs)
    assert logits.shape == (4, 10)
    labels = jnp.asarray([0, 1, 2, 3])
    (loss, m), g = jax.value_and_grad(vit_loss, has_aux=True)(
        params, cfg, imgs, labels
    )
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(g))


def test_vit_learns():
    """Tiny Hyena-ViT separates two synthetic classes in a few steps."""
    from repro.common.param import split_params
    from repro.models.vit import ViTConfig, init_vit, vit_loss
    from repro.train import optim as O

    cfg = ViTConfig(image_size=8, patch_size=4, d_model=16, n_layers=1,
                    d_ff=32, n_classes=2)
    params, _ = split_params(init_vit(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(32, 8, 8, 3)).astype(np.float32)
    labels = (imgs.mean(axis=(1, 2, 3)) > 0).astype(np.int32)
    imgs[labels == 1] += 0.8
    imgs_j, labels_j = jnp.asarray(imgs), jnp.asarray(labels)
    ocfg = O.AdamWConfig(lr=3e-3, warmup_steps=0, schedule="constant",
                         weight_decay=0.0)
    opt = O.init_adamw(params)
    losses = []
    step = jax.jit(lambda p, o: _step(p, o, cfg, imgs_j, labels_j, ocfg))
    for _ in range(25):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6, losses[::6]


def _step(params, opt, cfg, imgs, labels, ocfg):
    from repro.models.vit import vit_loss
    from repro.train import optim as O

    (loss, _), g = jax.value_and_grad(vit_loss, has_aux=True)(
        params, cfg, imgs, labels
    )
    params, opt, _ = O.adamw_update(ocfg, g, opt, params)
    return params, opt, loss
