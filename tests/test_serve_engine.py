"""Continuous-batching engine: randomized-schedule property harness.

The load-bearing claim (DESIGN.md §4, invariant I2): whatever the schedule
— arrival interleaving, slot contention, preemption/readmission — each
request's emitted tokens are identical to what the per-request sequential
``generate()`` would produce.  The harness draws random arrival times,
prompt lengths, horizons, stop conditions, and evictions, runs them through
a 2-slot engine, and compares token-for-token against the static reference,
for one architecture per decode-capable mixer family (covering all five
registered mixers: attention, local_attention, hyena, ssd, rglru).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import prop
from repro.common.param import split_params
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine, generate
from repro.serve.scheduler import SamplingParams

# one arch per decode-capable mixer family; recurrentgemma's pattern mixes
# rglru + local_attention and carries an unstacked tail layer
HARNESS_ARCHS = [
    "phi4-mini-3.8b",     # attention
    "recurrentgemma-2b",  # rglru + local_attention (+ tail)
    "hyena-153m",         # hyena
    "mamba2-130m",        # ssd
]

MAX_LEN = 24
H_MAX = 4  # reference horizon; per-request horizons are <= H_MAX
SCFG = ServeConfig(max_len=MAX_LEN, temperature=0.0, n_slots=2,
                   cache_dtype=jnp.float32)


@functools.lru_cache(maxsize=None)
def setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    return cfg, params


@functools.partial(jax.jit, static_argnames=("cfg",))
def _reference(params, prompt, *, cfg):
    """Sequential single-request reference at the engine's max_len grid."""
    return generate(params, cfg, prompt, scfg=SCFG, max_new_tokens=H_MAX)


def expected_tokens(ref, req_params):
    """Apply the engine's stop semantics to the sequential reference: emit
    up to max_new_tokens, stop *after* (and including) a stop token."""
    out = []
    for t in ref[: req_params.max_new_tokens]:
        out.append(int(t))
        if int(t) in req_params.stop_tokens:
            break
    return out


def run_schedule(arch, rng):
    cfg, params = setup(arch)
    eng = ServeEngine(params, cfg, SCFG)
    n_req = int(rng.integers(2, 5))
    plan = []
    for _ in range(n_req):
        L = int(rng.integers(3, 7))  # prompt length 3..6
        plan.append({
            "arrival": int(rng.integers(0, 4)),
            "prompt": rng.integers(0, cfg.vocab_size, size=L).astype(np.int32),
            "max_new": int(rng.integers(1, H_MAX + 1)),
            # ~half the requests can stop early on 2 random token ids
            "stop": tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, size=2)
            ) if rng.random() < 0.5 else (),
        })
    plan.sort(key=lambda p: p["arrival"])
    rids, t, evicted = {}, 0, []
    pending = list(plan)
    while pending or not eng.scheduler.idle:
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            rids[eng.submit(p["prompt"], max_new_tokens=p["max_new"],
                            stop_tokens=p["stop"])] = p
        # random preemption: readmission must reconstruct the slot state
        if len(evicted) < 2 and eng.scheduler.active and rng.random() < 0.3:
            victim = int(rng.choice(
                [r.rid for r in eng.scheduler.active.values()]
            ))
            if eng.evict(victim):
                evicted.append(victim)
        eng.step()
        t += 1
        assert t < 200, "schedule failed to drain"
    results = eng.results()
    for rid, p in rids.items():
        ref = np.asarray(
            _reference(params, jnp.asarray(p["prompt"])[None], cfg=cfg)[0]
        )
        want = expected_tokens(ref, SamplingParams(
            max_new_tokens=p["max_new"], stop_tokens=p["stop"],
        ))
        got = [int(x) for x in results[rid]]
        assert got == want, (
            f"{arch}: rid {rid} (evicted={rid in evicted}) diverged: "
            f"{got} != {want}"
        )
    # I3: after drain every slot is free and its per-slot state is zeroed
    assert eng.scheduler.idle
    axes = lm.cache_slot_axes(cfg, eng.pool)
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(
            lambda ax, leaf: jnp.zeros(()) if ax < 0
            else jnp.sum(jnp.abs(leaf.astype(jnp.float32))),
            axes, eng.pool,
        )
    )
    assert all(float(x) == 0.0 for x in leaves), "slot state leaked"


def _make_harness(arch):
    @prop.given(seed=prop.integers(0, 1 << 30))
    def harness(seed):
        run_schedule(arch, np.random.default_rng(seed))

    harness.__name__ = f"test_randomized_schedule_{arch.replace('-', '_')}"
    return pytest.mark.slow(harness)


for _arch in HARNESS_ARCHS:
    _t = _make_harness(_arch)
    globals()[_t.__name__] = _t
del _t


def test_schedule_smoke_deterministic():
    """Fast-tier pin: one fixed mixed schedule with eviction, all archs'
    cheapest member (hyena), token-identical to the reference."""
    run_schedule("hyena-153m", np.random.default_rng(1234))


def test_decode_quantum_token_identical():
    """Fusing multiple decode steps per scheduler tick changes wall-clock
    behavior only: outputs (incl. stop-token truncation mid-quantum) are
    identical to quantum=1."""
    cfg, params = setup("hyena-153m")
    outs = []
    for quantum in (1, 3):
        scfg = dataclasses.replace(SCFG, decode_quantum=quantum)
        eng = ServeEngine(params, cfg, scfg)
        r0 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=4)
        ref = np.asarray(
            _reference(params, jnp.asarray([[3, 5, 7, 2]]), cfg=cfg)[0]
        )
        # stop on the reference's 2nd token: truncation lands mid-quantum
        r1 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=4,
                        stop_tokens=(int(ref[1]),))
        out = eng.drain()
        outs.append((list(out[r0]), list(out[r1])))
    assert outs[0] == outs[1], outs
    assert outs[0][0] == [int(t) for t in ref[:4]]
    assert outs[0][1] == [int(t) for t in ref[:2]]


def test_streaming_and_per_request_sampling_params():
    """Streaming callbacks fire once per token in emission order; requests
    with different temperature/top_k coexist in one pool and sampled
    requests are schedule-deterministic (same rid/seed -> same tokens)."""
    cfg, params = setup("hyena-153m")
    got = []
    eng = ServeEngine(params, cfg, SCFG, seed=7)
    r0 = eng.submit(np.array([3, 5, 7]), max_new_tokens=3,
                    stream=lambda rid, tok, done: got.append((rid, tok, done)))
    r1 = eng.submit(np.array([2, 4]), max_new_tokens=3, temperature=0.9,
                    top_k=8)
    out = eng.drain()
    assert [g[0] for g in got].count(r0) == 3
    assert got[-1][2] or any(d for _, _, d in got)
    assert [t for rid, t, _ in got if rid == r0] == [int(x) for x in out[r0]]
    # re-running the sampled request alone reproduces its tokens exactly
    eng2 = ServeEngine(params, cfg, SCFG, seed=7)
    eng2._next_rid = r1  # same rid -> same per-request key stream
    r1b = eng2.submit(np.array([2, 4]), max_new_tokens=3, temperature=0.9,
                      top_k=8)
    out2 = eng2.drain()
    assert [int(x) for x in out2[r1b]] == [int(x) for x in out[r1]]


def test_finished_requests_are_pruned_and_poppable():
    """A long-lived engine must not retain finished Request objects; the
    tokens remain retrievable until popped."""
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)
    rid = eng.submit(np.array([1, 2, 3]), max_new_tokens=2)
    out = eng.drain()
    assert rid not in eng._requests  # prompt/callback closure released
    toks = eng.pop_result(rid)
    assert list(toks) == [int(t) for t in out[rid]]
    assert rid not in eng.results()


def test_stream_callback_exception_keeps_state_consistent():
    """A raising stream callback must not desync tokens from caches: all
    bookkeeping lands before callbacks fire, so results() still returns
    the full reference output."""
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)

    def boom(rid, tok, done):
        raise RuntimeError("consumer bug")

    r0 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=3, stream=boom)
    with pytest.raises(RuntimeError, match="consumer bug"):
        while not eng.scheduler.idle:
            eng.step()
    # recover: detach the broken callback and keep stepping
    if r0 in eng._requests:
        eng._requests[r0].stream = None
    out = eng.drain()
    ref = np.asarray(
        _reference(params, jnp.asarray([[3, 5, 7, 2]]), cfg=cfg)[0]
    )
    assert [int(t) for t in out[r0]] == [int(t) for t in ref[:3]]


def test_submit_validation():
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(MAX_LEN), max_new_tokens=1)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.array([1]), max_new_tokens=0)
