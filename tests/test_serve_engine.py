"""Continuous-batching engine: randomized-schedule property harness.

The load-bearing claim (DESIGN.md §4, invariant I2): whatever the schedule
— arrival interleaving, slot contention, preemption/readmission — each
request's emitted tokens are identical to what the per-request sequential
``generate()`` would produce.  The harness draws random arrival times,
prompt lengths, horizons, stop conditions, and evictions, runs them through
a 2-slot engine, and compares token-for-token against the static reference,
for one architecture per decode-capable mixer family (covering all five
registered mixers: attention, local_attention, hyena, ssd, rglru).
"""
import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import prop
from repro.common.param import split_params
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine, generate
from repro.serve.scheduler import SamplingParams

# one arch per decode-capable mixer family; recurrentgemma's pattern mixes
# rglru + local_attention and carries an unstacked tail layer
HARNESS_ARCHS = [
    "phi4-mini-3.8b",     # attention
    "recurrentgemma-2b",  # rglru + local_attention (+ tail)
    "hyena-153m",         # hyena
    "mamba2-130m",        # ssd
    "hyena-mh-small",     # hyena_se + hyena_mr + hyena_li + attention
]

MAX_LEN = 24
H_MAX = 4  # reference horizon; per-request horizons are <= H_MAX

# The randomized harnesses compile hundreds of tiny programs; on 1-core
# boxes XLA's backend_compile has been observed to segfault partway through
# the full suite (PR 9 flake).  The fixed-seed fast-tier pins below keep
# coverage everywhere; the long randomized sweeps only run with >= 2 cores
# (CI runners and dev machines), where the crash does not reproduce.
_NEEDS_CORES = pytest.mark.skipif(
    (os.cpu_count() or 1) < 2,
    reason="randomized serve harnesses segfault XLA backend_compile on "
    "1-core hosts; fixed-seed pins cover the fast tier",
)
SCFG = ServeConfig(max_len=MAX_LEN, temperature=0.0, n_slots=2,
                   cache_dtype=jnp.float32)


@functools.lru_cache(maxsize=None)
def setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    return cfg, params


@functools.partial(jax.jit, static_argnames=("cfg",))
def _reference(params, prompt, *, cfg):
    """Sequential single-request reference at the engine's max_len grid."""
    return generate(params, cfg, prompt, scfg=SCFG, max_new_tokens=H_MAX)


def expected_tokens(ref, req_params):
    """Apply the engine's stop semantics to the sequential reference: emit
    up to max_new_tokens, stop *after* (and including) a stop token."""
    out = []
    for t in ref[: req_params.max_new_tokens]:
        out.append(int(t))
        if int(t) in req_params.stop_tokens:
            break
    return out


def run_schedule(arch, rng):
    cfg, params = setup(arch)
    eng = ServeEngine(params, cfg, SCFG)
    n_req = int(rng.integers(2, 5))
    plan = []
    for _ in range(n_req):
        L = int(rng.integers(3, 7))  # prompt length 3..6
        plan.append({
            "arrival": int(rng.integers(0, 4)),
            "prompt": rng.integers(0, cfg.vocab_size, size=L).astype(np.int32),
            "max_new": int(rng.integers(1, H_MAX + 1)),
            # ~half the requests can stop early on 2 random token ids
            "stop": tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, size=2)
            ) if rng.random() < 0.5 else (),
        })
    plan.sort(key=lambda p: p["arrival"])
    rids, t, evicted = {}, 0, []
    pending = list(plan)
    while pending or not eng.scheduler.idle:
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            rids[eng.submit(p["prompt"], max_new_tokens=p["max_new"],
                            stop_tokens=p["stop"])] = p
        # random preemption: readmission must reconstruct the slot state
        if len(evicted) < 2 and eng.scheduler.active and rng.random() < 0.3:
            victim = int(rng.choice(
                [r.rid for r in eng.scheduler.active.values()]
            ))
            if eng.evict(victim):
                evicted.append(victim)
        eng.step()
        t += 1
        assert t < 200, "schedule failed to drain"
    results = eng.results()
    for rid, p in rids.items():
        ref = np.asarray(
            _reference(params, jnp.asarray(p["prompt"])[None], cfg=cfg)[0]
        )
        want = expected_tokens(ref, SamplingParams(
            max_new_tokens=p["max_new"], stop_tokens=p["stop"],
        ))
        got = [int(x) for x in results[rid]]
        assert got == want, (
            f"{arch}: rid {rid} (evicted={rid in evicted}) diverged: "
            f"{got} != {want}"
        )
    # I3: after drain every slot is free and its per-slot state is zeroed
    assert eng.scheduler.idle
    axes = lm.cache_slot_axes(cfg, eng.pool)
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(
            lambda ax, leaf: jnp.zeros(()) if ax < 0
            else jnp.sum(jnp.abs(leaf.astype(jnp.float32))),
            axes, eng.pool,
        )
    )
    assert all(float(x) == 0.0 for x in leaves), "slot state leaked"


def _make_harness(arch):
    @prop.given(seed=prop.integers(0, 1 << 30))
    def harness(seed):
        run_schedule(arch, np.random.default_rng(seed))

    harness.__name__ = f"test_randomized_schedule_{arch.replace('-', '_')}"
    return _NEEDS_CORES(pytest.mark.slow(harness))


for _arch in HARNESS_ARCHS:
    _t = _make_harness(_arch)
    globals()[_t.__name__] = _t
del _t


def test_schedule_smoke_deterministic():
    """Fast-tier pin: one fixed mixed schedule with eviction, all archs'
    cheapest member (hyena), token-identical to the reference."""
    run_schedule("hyena-153m", np.random.default_rng(1234))


def test_schedule_smoke_multihybrid():
    """Fast-tier pin (ISSUE 9 acceptance): the SE-MR-LI-attn multi-hybrid
    pattern — three hyena tiers with distinct cache layouts plus attention
    in ONE network — serves token-identically through the dense engine on
    a fixed mixed schedule with eviction."""
    run_schedule("hyena-mh-small", np.random.default_rng(77))


def test_decode_quantum_token_identical():
    """Fusing multiple decode steps per scheduler tick changes wall-clock
    behavior only: outputs (incl. stop-token truncation mid-quantum) are
    identical to quantum=1."""
    cfg, params = setup("hyena-153m")
    outs = []
    for quantum in (1, 3):
        scfg = dataclasses.replace(SCFG, decode_quantum=quantum)
        eng = ServeEngine(params, cfg, scfg)
        r0 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=4)
        ref = np.asarray(
            _reference(params, jnp.asarray([[3, 5, 7, 2]]), cfg=cfg)[0]
        )
        # stop on the reference's 2nd token: truncation lands mid-quantum
        r1 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=4,
                        stop_tokens=(int(ref[1]),))
        out = eng.drain()
        outs.append((list(out[r0]), list(out[r1])))
    assert outs[0] == outs[1], outs
    assert outs[0][0] == [int(t) for t in ref[:4]]
    assert outs[0][1] == [int(t) for t in ref[:2]]


def test_streaming_and_per_request_sampling_params():
    """Streaming callbacks fire once per token in emission order; requests
    with different temperature/top_k coexist in one pool and sampled
    requests are schedule-deterministic (same rid/seed -> same tokens)."""
    cfg, params = setup("hyena-153m")
    got = []
    eng = ServeEngine(params, cfg, SCFG, seed=7)
    r0 = eng.submit(np.array([3, 5, 7]), max_new_tokens=3,
                    stream=lambda rid, tok, done: got.append((rid, tok, done)))
    r1 = eng.submit(np.array([2, 4]), max_new_tokens=3, temperature=0.9,
                    top_k=8)
    out = eng.drain()
    assert [g[0] for g in got].count(r0) == 3
    assert got[-1][2] or any(d for _, _, d in got)
    assert [t for rid, t, _ in got if rid == r0] == [int(x) for x in out[r0]]
    # re-running the sampled request alone reproduces its tokens exactly
    eng2 = ServeEngine(params, cfg, SCFG, seed=7)
    eng2._next_rid = r1  # same rid -> same per-request key stream
    r1b = eng2.submit(np.array([2, 4]), max_new_tokens=3, temperature=0.9,
                      top_k=8)
    out2 = eng2.drain()
    assert [int(x) for x in out2[r1b]] == [int(x) for x in out[r1]]


def test_finished_requests_are_pruned_and_poppable():
    """A long-lived engine must not retain finished Request objects; the
    tokens remain retrievable until popped."""
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)
    rid = eng.submit(np.array([1, 2, 3]), max_new_tokens=2)
    out = eng.drain()
    assert rid not in eng._requests  # prompt/callback closure released
    toks = eng.pop_result(rid)
    assert list(toks) == [int(t) for t in out[rid]]
    assert rid not in eng.results()


def test_stream_callback_exception_keeps_state_consistent():
    """A raising stream callback must not desync tokens from caches: all
    bookkeeping lands before callbacks fire, so results() still returns
    the full reference output."""
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)

    def boom(rid, tok, done):
        raise RuntimeError("consumer bug")

    r0 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=3, stream=boom)
    with pytest.raises(RuntimeError, match="consumer bug"):
        while not eng.scheduler.idle:
            eng.step()
    # recover: detach the broken callback and keep stepping
    if r0 in eng._requests:
        eng._requests[r0].stream = None
    out = eng.drain()
    ref = np.asarray(
        _reference(params, jnp.asarray([[3, 5, 7, 2]]), cfg=cfg)[0]
    )
    assert [int(t) for t in out[r0]] == [int(t) for t in ref[:3]]


def test_submit_validation():
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(MAX_LEN), max_new_tokens=1)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.array([1]), max_new_tokens=0)


# ---------------------------------------------------------------- paged
#
# The paged engine's randomized harness (reference construction, tie-aware
# comparison, plan generation) lives in tests/serve_parity.py so the
# distributed suite can drive the identical scenarios in its 8-device
# subprocesses; here we pin fixed seeds in the fast tier, run the full
# property sweep per mixer family in the slow tier, and unit-test the
# paged substrate (allocator, SLO queue, radix tree, COW, drain budgets).
import serve_parity
from repro.serve.engine import DrainExhausted, request_token_key
from repro.serve.paged import BlockAllocator, PagedConfig, PagedServeEngine
from repro.serve.radix import RadixPrefixCache
from repro.serve.sampling import sample_slots
from repro.serve.slo import SLOQueue

PCFG = PagedConfig(page_size=4)


def test_paged_schedule_fixed_seed():
    """Fast-tier pin: one fixed randomized paged schedule (prefix sharing,
    chunked prefill, eviction + radix chaos) on hyena, tie-aware
    token-identical to the sequential reference."""
    serve_parity.check_paged_schedule("hyena-153m", 1234)


def test_paged_schedule_fixed_seed_multihybrid():
    """Fast-tier pin (ISSUE 9 acceptance): the SE-MR-LI-attn multi-hybrid
    through the PAGED engine — SE/MR rolling windows are pinned state, LI
    operand history is paged, attention KV is paged — one fixed randomized
    paged schedule, tie-aware token-identical to the reference."""
    serve_parity.check_paged_schedule("hyena-mh-small", 77)


def _make_paged_harness(arch):
    @prop.given(seed=prop.integers(0, 1 << 30))
    def harness(seed):
        serve_parity.check_paged_schedule(arch, seed)

    harness.__name__ = f"test_paged_randomized_{arch.replace('-', '_')}"
    return _NEEDS_CORES(pytest.mark.slow(harness))


for _arch in HARNESS_ARCHS:
    _t = _make_paged_harness(_arch)
    globals()[_t.__name__] = _t
del _t


def test_paged_prefix_fork_restores_pinned_state():
    """Two staggered requests sharing an 10-token system prompt: the
    second forks the radix prefix (8 cached tokens at page 4) and both
    emit exactly the sequential reference — on hyena, whose cache mixes
    paged operand history with pinned short-conv windows and cursors, so
    a fork is only correct if the pinned snapshot is restored too."""
    cfg, params, _ = serve_parity.setup("hyena-153m")
    scfg = serve_parity.SCFG
    eng = PagedServeEngine(params, cfg, scfg, PCFG)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    r0 = eng.submit(shared, max_new_tokens=4)
    out = dict(eng.drain())  # r0 finishes; its prefix pages are inserted
    p1 = np.concatenate([shared, [5, 7]]).astype(np.int32)
    r1 = eng.submit(p1, max_new_tokens=4)
    out.update(eng.drain())
    assert eng.request_metrics[r1]["prefix_cached_tokens"] == 8
    for rid, prompt in ((r0, shared), (r1, p1)):
        ref = np.asarray(generate(
            params, cfg, jnp.asarray(prompt[None]), scfg=scfg,
            max_new_tokens=4,
        ))[0]
        assert [int(t) for t in out[rid]] == [int(t) for t in ref], rid
    eng.flush_prefix()
    eng.check_clean()


def test_paged_sampled_schedule_independent():
    """A sampled request's tokens depend only on (seed, rid, token index):
    forking a cached prefix vs prefilling from scratch yields the same
    stream."""
    cfg, params, _ = serve_parity.setup("hyena-153m")
    scfg = serve_parity.SCFG
    prompt = np.arange(1, 9, dtype=np.int32)
    outs = []
    for prefix_cache in (True, False):
        eng = PagedServeEngine(
            params, cfg, scfg,
            PagedConfig(page_size=4, prefix_cache=prefix_cache),
        )
        warm = eng.submit(prompt, max_new_tokens=2)
        for _ in range(4):
            eng.step()
        eng._next_rid = 17  # same rid -> same per-request key stream
        rid = eng.submit(prompt, max_new_tokens=4, temperature=0.9,
                         top_k=8)
        out = eng.drain()
        outs.append([int(t) for t in out[rid]])
        del warm
    assert outs[0] == outs[1], outs


def test_sampled_scores_reproduces_sample_slots():
    """The parity harness's reference reproduces sample_slots exactly: a
    sampled row's token is the argmax of the temperature-scaled, top-k
    masked, gumbel-perturbed logits under the same per-request key."""
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    temps = jnp.asarray([0.0, 0.8, 1.3, 0.5], jnp.float32)
    topks = jnp.asarray([0, 0, 8, 3], jnp.int32)
    base = jax.random.PRNGKey(0)
    keys = jnp.stack([
        request_token_key(base, jnp.asarray(r, jnp.int32),
                          jnp.asarray(2, jnp.int32))
        for r in range(4)
    ])
    got = sample_slots(keys, logits, temps, topks)
    for r in range(4):
        want = int(jnp.argmax(serve_parity.sampled_scores(
            keys[r], logits[r], float(temps[r]), int(topks[r]),
        )))
        assert int(got[r]) == want, r


def test_scheduler_readmission_beats_new_arrivals():
    """Starvation regression (dense engine): an evicted request re-enters
    AHEAD of queued arrivals — under a 1-slot pool with a backlog, FIFO
    requeue would park the victim behind every arrival forever."""
    cfg, params = setup("hyena-153m")
    scfg = dataclasses.replace(SCFG, n_slots=1)
    eng = ServeEngine(params, cfg, scfg)
    prompts = {
        "a": np.array([3, 5, 7, 2], np.int32),
        "b": np.array([4, 1, 6], np.int32),
        "c": np.array([2, 2, 9], np.int32),
    }
    ra = eng.submit(prompts["a"], max_new_tokens=6)
    eng.step()  # a resident (admission prefill + one decode: 2 tokens out)
    rb = eng.submit(prompts["b"], max_new_tokens=2)
    rc = eng.submit(prompts["c"], max_new_tokens=2)
    assert eng.evict(ra)
    assert [r.rid for r in eng.scheduler.readmit] == [ra]
    eng.step()
    resident = [r.rid for r in eng.scheduler.active.values()]
    assert resident == [ra], (
        f"evicted request lost its turn to a new arrival: {resident}"
    )
    out = eng.drain()
    for rid, key, n in ((ra, "a", 6), (rb, "b", 2), (rc, "c", 2)):
        ref = np.asarray(generate(
            params, cfg, jnp.asarray(prompts[key][None]), scfg=scfg,
            max_new_tokens=n,
        ))[0]
        assert [int(t) for t in out[rid]] == [int(t) for t in ref[:n]], key


@pytest.mark.parametrize("paged", [False, True])
def test_drain_budget_raises_with_partial_results(paged):
    """drain(max_steps) out of budget raises DrainExhausted carrying the
    partial rid -> tokens map and active rids; the engine stays
    consistent, so a follow-up drain finishes the work."""
    if paged:
        cfg, params, _ = serve_parity.setup("hyena-153m")
        eng = PagedServeEngine(params, cfg, serve_parity.SCFG, PCFG)
    else:
        cfg, params = setup("hyena-153m")
        eng = ServeEngine(params, cfg, SCFG)
    prompt = np.array([3, 5, 7, 2], np.int32)
    rid = eng.submit(prompt, max_new_tokens=4)
    with pytest.raises(DrainExhausted) as ei:
        eng.drain(max_steps=1)
    err = ei.value
    assert err.max_steps == 1 and err.active == (rid,)
    assert rid in err.partial and len(err.partial[rid]) < 4
    assert "still active" in str(err)
    # budget exhaustion must not leak: the still-active request was
    # evicted to the readmit queue and its slot/blocks released BEFORE
    # the raise — the pool is fully free (radix-held blocks excepted,
    # reclaimed by flush_prefix)
    if paged:
        assert not eng.residents
        eng.flush_prefix()
        assert eng.alloc.n_free == eng.alloc.n_blocks - 1, "leaked blocks"
        assert not eng.alloc.ref.any(), "leaked refcounts"
    else:
        assert not eng.scheduler.active
        serve_parity.assert_pool_zeroed(eng)
    out = eng.drain()  # resumes exactly where the budget cut off
    ref = np.asarray(generate(
        params, cfg, jnp.asarray(prompt[None]), scfg=serve_parity.SCFG,
        max_new_tokens=4,
    ))[0]
    assert [int(t) for t in out[rid]] == [int(t) for t in ref]


def test_cow_copies_shared_block_before_write():
    """_ensure_writable on a block whose refcount > 1 allocates a private
    copy, moves the slot's table entry, and preserves contents byte-for-
    byte — the safety net partial-page forks would rely on."""
    cfg, params, _ = serve_parity.setup("hyena-153m")
    eng = PagedServeEngine(params, cfg, serve_parity.SCFG, PCFG)
    b = eng.alloc.alloc()
    eng.alloc.incref(b)  # simulate a second owner (radix node / fork)
    marked = []
    for j, i in enumerate(eng.spec.paged_idx):
        s = eng.spec.slot_axes[i]
        idx = (slice(None),) * s + (b,)
        eng._phys[j] = eng._phys[j].at[idx].set(1.5)
        marked.append((j, s))
    eng._table[0, 0] = b
    assert eng._ensure_writable(0, 0, 1)
    nb = int(eng._table[0, 0])
    assert nb != b and nb != 0
    assert int(eng.alloc.ref[b]) == 1 and int(eng.alloc.ref[nb]) == 1
    for j, s in marked:
        src = np.asarray(jnp.take(eng._phys[j], b, axis=s), np.float32)
        dst = np.asarray(jnp.take(eng._phys[j], nb, axis=s), np.float32)
        np.testing.assert_array_equal(dst, src)
        assert float(np.abs(dst).sum()) > 0.0


def test_block_allocator_unit():
    alloc = BlockAllocator(4)
    assert alloc.n_free == 3  # block 0 is the reserved trash block
    a, b, c = alloc.alloc(), alloc.alloc(), alloc.alloc()
    assert (a, b, c) == (1, 2, 3) and alloc.alloc() is None
    alloc.incref(b)
    assert not alloc.decref(b) and alloc.n_free == 0
    assert alloc.decref(b) and alloc.n_free == 1
    assert alloc.alloc() == b  # freed block recycles
    with pytest.raises(ValueError):
        BlockAllocator(1)


def test_slo_queue_ordering_unit():
    """Admission order: readmits first, then priority (higher wins), then
    deadline (earlier wins), then arrival order."""
    q = SLOQueue()
    q.push(0, priority=0)
    q.push(1, priority=2)
    q.push(2, priority=2, deadline=5)
    q.push(3, priority=2, deadline=3)
    q.push(4, priority=0)
    assert q.peek() == (3, False) and q.peek_priority() == 2
    q.push_readmit(9)
    assert q.peek() == (9, True)
    assert q.peek_priority() == 2  # readmits never trigger preemption
    assert list(q.rids())[0] == 9
    assert [q.pop() for _ in range(len(q))] == [9, 3, 2, 1, 0, 4]
    q.push(5, priority=1)
    q.push(6, priority=1)
    assert q.remove(5) and not q.remove(5)
    assert q.pop() == 6 and q.pop() is None


def test_radix_prefix_cache_unit():
    alloc = BlockAllocator(8)
    radix = RadixPrefixCache(2, alloc)
    a, b = alloc.alloc(), alloc.alloc()
    with pytest.raises(ValueError, match="page-aligned"):
        radix.insert((1, 2, 3), [a, b], ["snap"])
    # the engine inserts at every page boundary as prefill advances, so
    # each node carries the snapshot taken when it was the frontier
    assert radix.insert((1, 2), [a], ["snap1"])
    assert radix.insert((1, 2, 3, 4), [a, b], ["snap2"])
    assert radix.n_nodes == 2
    assert int(alloc.ref[a]) == 2 and int(alloc.ref[b]) == 2
    # longest whole-page match, capped at len - 1 (a token must remain)
    depth, blocks, snap = radix.match((1, 2, 3, 4, 5))
    assert (depth, blocks, snap) == (4, [a, b], ["snap2"])
    assert radix.match((1, 2, 3, 4))[:1] == (2,)  # cap: limit = 3
    assert radix.match((9, 9, 9))[0] == 0
    # the donor finished: it drops its own refs, the tree keeps the blocks
    alloc.decref(a), alloc.decref(b)
    assert radix.evict_lru(1) == [b]  # leaf only; ref hit zero
    assert radix.n_nodes == 1 and alloc.n_free == 6
    assert radix.match((1, 2, 3, 4, 5))[:2] == (2, [a])
    assert radix.flush() == [a]
    assert radix.n_nodes == 0 and alloc.n_free == 7


def test_paged_submit_validation():
    cfg, params, _ = serve_parity.setup("hyena-153m")
    eng = PagedServeEngine(
        params, cfg, serve_parity.SCFG,
        PagedConfig(page_size=4, n_blocks=3),  # 2 usable = 8 tokens
    )
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(np.arange(8), max_new_tokens=4)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(MAX_LEN), max_new_tokens=1)
    eng.submit(np.arange(4), max_new_tokens=4)  # exactly 2 blocks: fits
    eng.drain()


# ---------------------------------------------------------------- faults
#
# The serve fault contract (DESIGN.md §13): deterministic seeded fault
# injection (NaN/Inf logit poisoning, transient step/prefill errors,
# allocator exhaustion), NaN-quarantined decode with evict-replay,
# request lifecycle guards (cancel / deadline / shed), and the chaos
# property harness shared with the distributed suite via serve_parity.
from repro.serve.faults import FaultInjector, FaultPlan, TransientStepError
from repro.serve.scheduler import TERMINAL_STATUSES


def _build(arch, paged, scfg=None, pcfg=None, injector=None):
    if paged:
        cfg, params, _ = serve_parity.setup(arch)
        eng = PagedServeEngine(params, cfg, scfg or serve_parity.SCFG,
                               pcfg or PCFG, injector=injector)
    else:
        cfg, params = setup(arch)
        eng = ServeEngine(params, cfg, scfg or SCFG, injector=injector)
    return cfg, params, eng


@pytest.mark.parametrize("arch", HARNESS_ARCHS)
@pytest.mark.parametrize("paged", [False, True])
def test_quarantine_replay_token_identical(arch, paged):
    """A request whose decode logits are NaN-poisoned is quarantined
    (blocks/slot released) and replayed from its last good token; the
    replayed stream AND the unfaulted neighbor's sampled stream are
    bit-identical to the fault-free engine run — schedule-independent
    (seed, rid, token-index) key streams make the replay exact, and
    per-slot batch independence keeps the poison out of neighbor
    caches."""
    prompts = {
        0: np.array([3, 5, 7, 2], np.int32),
        1: np.array([4, 1, 6], np.int32),
    }
    outs = {}
    for faulted in (False, True):
        inj = FaultInjector(FaultPlan(
            poison_tokens=((0, 1, "nan"),)
        )) if faulted else None
        cfg, params, eng = _build(arch, paged, injector=inj)
        r0 = eng.submit(prompts[0], max_new_tokens=4)
        r1 = eng.submit(prompts[1], max_new_tokens=4, temperature=0.8,
                        top_k=8)
        out = eng.drain()
        outs[faulted] = {r: [int(t) for t in out[r]] for r in (r0, r1)}
        if faulted:
            assert eng.n_quarantined == 1 and inj.fired["nan"] >= 1
            assert eng.result(r0).status == "completed"
            assert eng.result(r1).status == "completed"
        if paged:
            eng.flush_prefix()
            eng.check_clean()
        else:
            serve_parity.assert_pool_zeroed(eng)
    assert outs[True] == outs[False], (
        f"{arch} paged={paged}: replayed run diverged from fault-free: "
        f"{outs[True]} != {outs[False]}"
    )


@pytest.mark.parametrize("paged", [False, True])
def test_quarantine_strikes_out_structurally(paged):
    """Persistent poison (every replay re-poisoned) exhausts
    quarantine_strikes: the request fails with a structured result
    carrying its last-good partial tokens, and the pool comes back
    clean."""
    prompt = np.array([3, 5, 7, 2], np.int32)
    cfg, params, eng = _build("hyena-153m", paged)
    r = eng.submit(prompt, max_new_tokens=4)
    base = [int(t) for t in eng.drain()[r]]

    inj = FaultInjector(FaultPlan(
        poison_tokens=((0, 1, "inf"),), poison_attempts=99,
    ))
    cfg, params, eng = _build("hyena-153m", paged, injector=inj)
    r = eng.submit(prompt, max_new_tokens=4)
    eng.drain()
    res = eng.result(r)
    assert res.status == "failed" and not res.ok
    assert "quarantine" in res.detail
    assert list(res.tokens) == base[:1]  # last-good prefix, poison at t=1
    assert eng.n_quarantined == eng.scfg.quarantine_strikes
    if paged:
        eng.flush_prefix()
        eng.check_clean()
    else:
        serve_parity.assert_pool_zeroed(eng)


@pytest.mark.parametrize("paged", [False, True])
def test_transient_faults_absorbed_by_retry(paged):
    """Transient step/prefill errors (and paged allocator exhaustion) are
    retried with bounded backoff; retry-exhausted ticks surface
    TransientStepError to the caller but leave the engine consistent —
    the drained output is still token-identical to the fault-free run."""
    prompt = np.array([3, 5, 7, 2], np.int32)
    cfg, params, eng = _build("hyena-153m", paged)
    r = eng.submit(prompt, max_new_tokens=4)
    base = [int(t) for t in eng.drain()[r]]

    plan = FaultPlan(step_error_rate=0.4, prefill_error_rate=0.3,
                     alloc_fail_rate=0.3 if paged else 0.0, seed=3)
    inj = FaultInjector(plan)
    cfg, params, eng = _build("hyena-153m", paged, injector=inj)
    r = eng.submit(prompt, max_new_tokens=4)
    for _ in range(300):
        try:
            eng.step()
        except TransientStepError:
            pass
        if (eng.idle if paged else eng.scheduler.idle):
            break
    assert (eng.idle if paged else eng.scheduler.idle), "failed to drain"
    assert [int(t) for t in eng.results()[r]] == base
    assert eng.result(r).status == "completed"
    assert sum(inj.fired.values()) > 0, "no faults actually fired"
    if paged:
        eng.flush_prefix()
        eng.check_clean()


@pytest.mark.parametrize("paged", [False, True])
def test_cancel_releases_resources_mid_decode(paged):
    """cancel(rid) mid-decode finalizes the request as "cancelled" with
    its partial tokens, releases its slot/blocks immediately (a queued
    neighbor gets admitted), and the drained pool is clean.  Cancelling a
    queued or finished rid is safe."""
    cfg, params, eng = _build("hyena-153m", paged)
    ra = eng.submit(np.array([3, 5, 7, 2], np.int32), max_new_tokens=6)
    for _ in range(10):  # paged chunked prefill may take several quanta
        eng.step()
        if eng._requests[ra].n_emitted:
            break
    assert eng.cancel(ra)
    res = eng.result(ra)
    assert res.status == "cancelled" and 1 <= len(res.tokens) < 6
    assert not eng.cancel(ra)  # already terminal
    rb = eng.submit(np.array([4, 1, 6], np.int32), max_new_tokens=3)
    assert eng.cancel(eng.submit(np.array([9], np.int32),
                                 max_new_tokens=2))  # queued, never ran
    out = eng.drain()
    ref = np.asarray(generate(
        params, cfg, jnp.asarray([[4, 1, 6]]),
        scfg=serve_parity.SCFG if paged else SCFG, max_new_tokens=3,
    ))[0]
    assert [int(t) for t in out[rb]] == [int(t) for t in ref[:3]]
    if paged:
        eng.flush_prefix()
        eng.check_clean()
    else:
        serve_parity.assert_pool_zeroed(eng)


@pytest.mark.parametrize("paged", [False, True])
def test_deadline_exceeded_structured(paged):
    """A request that misses its tick deadline aborts with status
    "deadline_exceeded" and partial tokens; a deadline already expired at
    submit finalizes immediately without touching the pool."""
    cfg, params, eng = _build("hyena-153m", paged)
    rd = eng.submit(np.array([1, 2, 3], np.int32), max_new_tokens=8,
                    deadline=eng._tick + 1)
    eng.step()
    eng.step()
    eng.step()
    res = eng.result(rd)
    assert res.status == "deadline_exceeded"
    assert len(res.tokens) < 8  # partial output preserved, never complete
    re_ = eng.submit(np.array([1, 2], np.int32), max_new_tokens=2,
                     deadline=0)  # already expired
    assert eng.result(re_).status == "deadline_exceeded"
    assert eng.result(re_).tokens == ()
    eng.drain()
    if paged:
        eng.flush_prefix()
        eng.check_clean()
    else:
        serve_parity.assert_pool_zeroed(eng)


def test_load_shedding_drops_weakest_paged():
    """Past overload_threshold queued requests, the paged engine sheds
    the WEAKEST queued work (lowest priority, latest deadline, newest) —
    high-priority arrivals are never the victim."""
    cfg, params, eng = _build(
        "hyena-153m", True,
        scfg=dataclasses.replace(serve_parity.SCFG, overload_threshold=4),
    )
    prompt = np.array([1, 2, 3], np.int32)
    lo = eng.submit(prompt, max_new_tokens=4, priority=0)
    hi = [eng.submit(prompt, max_new_tokens=4, priority=2)
          for _ in range(4)]  # 5th queued arrival tips the threshold
    assert eng.result(lo) is not None and eng.result(lo).status == "shed"
    assert eng.n_shed == 1
    assert all(eng.result(r) is None for r in hi)  # none shed
    out = eng.drain()
    assert all(len(out[r]) == 4 for r in hi)
    eng.flush_prefix()
    eng.check_clean()


def test_load_shedding_dense_newest():
    """The dense queue is FIFO (no priorities): overload sheds the newest
    arrival, never admitted work."""
    cfg, params, eng = _build(
        "hyena-153m", False,
        scfg=dataclasses.replace(SCFG, overload_threshold=1),
    )
    prompt = np.array([1, 2, 3], np.int32)
    rids = [eng.submit(prompt, max_new_tokens=4) for _ in range(2)]
    # shedding is enforced AT SUBMIT on queue depth: the second arrival
    # tipped the queue past threshold 1 and was shed immediately
    eng.step()  # rid 0 admitted into a slot
    rids += [eng.submit(prompt, max_new_tokens=4) for _ in range(2)]
    shed = [r for r in rids
            if eng.result(r) is not None and eng.result(r).status == "shed"]
    assert shed == [rids[1], rids[3]], shed  # newest queued, never admitted
    eng.drain()
    assert eng.result(rids[0]).ok and eng.result(rids[2]).ok
    serve_parity.assert_pool_zeroed(eng)


def test_health_and_heartbeat(tmp_path):
    """health() exposes the liveness/saturation surface; the heartbeat
    file is written atomically every tick (see also the atomicity
    regression in test_train_substrate.py)."""
    hb = tmp_path / "serve.heartbeat"
    cfg, params, eng = _build(
        "hyena-153m", False,
        scfg=dataclasses.replace(SCFG, heartbeat_path=str(hb)),
    )
    assert hb.exists()  # initial beat at construction
    t0 = hb.read_text()
    eng.submit(np.array([1, 2, 3], np.int32), max_new_tokens=6)
    eng.step()
    h = eng.health()
    assert h["tick"] == 1 and h["resident"] == 1 and h["queued"] == 0
    assert h["heartbeat"] == str(hb) and hb.read_text() != t0
    eng.drain()
    h = eng.health()
    assert h["resident"] == 0 and h["finished"] == 1
    ph = _build("hyena-153m", True)[2].health()
    assert "free_blocks" in ph and "radix_nodes" in ph


def test_slo_queue_tombstones_unit():
    """Lazy-tombstone removal: remove() is O(1), removed rids never pop,
    worst() picks the shed victim (lowest priority, latest deadline,
    newest) and never a readmit."""
    q = SLOQueue()
    for i in range(6):
        q.push(i, priority=i % 3)
    assert q.remove(3) and not q.remove(3)
    assert len(q) == 5 and 3 not in list(q.rids())
    assert 3 not in [q.pop() for _ in range(len(q))]
    # worst(): priority dominates, then latest deadline, then newest
    q = SLOQueue()
    q.push(0, priority=1)
    q.push(1, priority=0, deadline=9)
    q.push(2, priority=0)  # no deadline sorts after any deadline
    q.push(3, priority=0)  # newest among the undeadlined weak
    assert q.worst() == 3
    q.push_readmit(7)
    assert q.worst() == 3  # readmits are never shed
    for r in (3, 2, 1, 0):
        assert q.remove(r)
        assert q.worst() not in (r, 7)
    assert q.worst() is None and q.pop() == 7 and q.pop() is None
    # interleaved remove/push keeps ordering consistent
    q = SLOQueue()
    for i in range(8):
        q.push(i, priority=0, deadline=i)
    for i in (0, 2, 4, 6):
        q.remove(i)
    q.push(8, priority=1)
    assert [q.pop() for _ in range(len(q))] == [8, 1, 3, 5, 7]


def test_chaos_fixed_seed_dense():
    """Fast-tier pin: one randomized chaos schedule (poison + transient
    errors + deadlines + cancels) on the dense engine — every request
    terminal and structured, completions token-identical, pool clean."""
    serve_parity.check_chaos_schedule("hyena-153m", 7)


def test_chaos_fixed_seed_paged():
    """Fast-tier pin: chaos on the paged engine (adds allocator
    exhaustion, priorities, chunked-prefill replay)."""
    serve_parity.check_chaos_schedule("hyena-153m", 11, paged=True)


def _make_chaos_harness(arch, paged):
    @prop.given(seed=prop.integers(0, 1 << 30))
    def harness(seed):
        serve_parity.check_chaos_schedule(arch, seed, paged=paged)

    harness.__name__ = (
        f"test_chaos_randomized_{'paged' if paged else 'dense'}"
        f"_{arch.replace('-', '_')}"
    )
    return _NEEDS_CORES(pytest.mark.slow(harness))


for _arch in HARNESS_ARCHS:
    for _paged in (False, True):
        _t = _make_chaos_harness(_arch, _paged)
        globals()[_t.__name__] = _t
del _t
