"""Continuous-batching engine: randomized-schedule property harness.

The load-bearing claim (DESIGN.md §4, invariant I2): whatever the schedule
— arrival interleaving, slot contention, preemption/readmission — each
request's emitted tokens are identical to what the per-request sequential
``generate()`` would produce.  The harness draws random arrival times,
prompt lengths, horizons, stop conditions, and evictions, runs them through
a 2-slot engine, and compares token-for-token against the static reference,
for one architecture per decode-capable mixer family (covering all five
registered mixers: attention, local_attention, hyena, ssd, rglru).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import prop
from repro.common.param import split_params
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine, generate
from repro.serve.scheduler import SamplingParams

# one arch per decode-capable mixer family; recurrentgemma's pattern mixes
# rglru + local_attention and carries an unstacked tail layer
HARNESS_ARCHS = [
    "phi4-mini-3.8b",     # attention
    "recurrentgemma-2b",  # rglru + local_attention (+ tail)
    "hyena-153m",         # hyena
    "mamba2-130m",        # ssd
]

MAX_LEN = 24
H_MAX = 4  # reference horizon; per-request horizons are <= H_MAX
SCFG = ServeConfig(max_len=MAX_LEN, temperature=0.0, n_slots=2,
                   cache_dtype=jnp.float32)


@functools.lru_cache(maxsize=None)
def setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    return cfg, params


@functools.partial(jax.jit, static_argnames=("cfg",))
def _reference(params, prompt, *, cfg):
    """Sequential single-request reference at the engine's max_len grid."""
    return generate(params, cfg, prompt, scfg=SCFG, max_new_tokens=H_MAX)


def expected_tokens(ref, req_params):
    """Apply the engine's stop semantics to the sequential reference: emit
    up to max_new_tokens, stop *after* (and including) a stop token."""
    out = []
    for t in ref[: req_params.max_new_tokens]:
        out.append(int(t))
        if int(t) in req_params.stop_tokens:
            break
    return out


def run_schedule(arch, rng):
    cfg, params = setup(arch)
    eng = ServeEngine(params, cfg, SCFG)
    n_req = int(rng.integers(2, 5))
    plan = []
    for _ in range(n_req):
        L = int(rng.integers(3, 7))  # prompt length 3..6
        plan.append({
            "arrival": int(rng.integers(0, 4)),
            "prompt": rng.integers(0, cfg.vocab_size, size=L).astype(np.int32),
            "max_new": int(rng.integers(1, H_MAX + 1)),
            # ~half the requests can stop early on 2 random token ids
            "stop": tuple(
                int(t) for t in rng.integers(0, cfg.vocab_size, size=2)
            ) if rng.random() < 0.5 else (),
        })
    plan.sort(key=lambda p: p["arrival"])
    rids, t, evicted = {}, 0, []
    pending = list(plan)
    while pending or not eng.scheduler.idle:
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            rids[eng.submit(p["prompt"], max_new_tokens=p["max_new"],
                            stop_tokens=p["stop"])] = p
        # random preemption: readmission must reconstruct the slot state
        if len(evicted) < 2 and eng.scheduler.active and rng.random() < 0.3:
            victim = int(rng.choice(
                [r.rid for r in eng.scheduler.active.values()]
            ))
            if eng.evict(victim):
                evicted.append(victim)
        eng.step()
        t += 1
        assert t < 200, "schedule failed to drain"
    results = eng.results()
    for rid, p in rids.items():
        ref = np.asarray(
            _reference(params, jnp.asarray(p["prompt"])[None], cfg=cfg)[0]
        )
        want = expected_tokens(ref, SamplingParams(
            max_new_tokens=p["max_new"], stop_tokens=p["stop"],
        ))
        got = [int(x) for x in results[rid]]
        assert got == want, (
            f"{arch}: rid {rid} (evicted={rid in evicted}) diverged: "
            f"{got} != {want}"
        )
    # I3: after drain every slot is free and its per-slot state is zeroed
    assert eng.scheduler.idle
    axes = lm.cache_slot_axes(cfg, eng.pool)
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(
            lambda ax, leaf: jnp.zeros(()) if ax < 0
            else jnp.sum(jnp.abs(leaf.astype(jnp.float32))),
            axes, eng.pool,
        )
    )
    assert all(float(x) == 0.0 for x in leaves), "slot state leaked"


def _make_harness(arch):
    @prop.given(seed=prop.integers(0, 1 << 30))
    def harness(seed):
        run_schedule(arch, np.random.default_rng(seed))

    harness.__name__ = f"test_randomized_schedule_{arch.replace('-', '_')}"
    return pytest.mark.slow(harness)


for _arch in HARNESS_ARCHS:
    _t = _make_harness(_arch)
    globals()[_t.__name__] = _t
del _t


def test_schedule_smoke_deterministic():
    """Fast-tier pin: one fixed mixed schedule with eviction, all archs'
    cheapest member (hyena), token-identical to the reference."""
    run_schedule("hyena-153m", np.random.default_rng(1234))


def test_decode_quantum_token_identical():
    """Fusing multiple decode steps per scheduler tick changes wall-clock
    behavior only: outputs (incl. stop-token truncation mid-quantum) are
    identical to quantum=1."""
    cfg, params = setup("hyena-153m")
    outs = []
    for quantum in (1, 3):
        scfg = dataclasses.replace(SCFG, decode_quantum=quantum)
        eng = ServeEngine(params, cfg, scfg)
        r0 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=4)
        ref = np.asarray(
            _reference(params, jnp.asarray([[3, 5, 7, 2]]), cfg=cfg)[0]
        )
        # stop on the reference's 2nd token: truncation lands mid-quantum
        r1 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=4,
                        stop_tokens=(int(ref[1]),))
        out = eng.drain()
        outs.append((list(out[r0]), list(out[r1])))
    assert outs[0] == outs[1], outs
    assert outs[0][0] == [int(t) for t in ref[:4]]
    assert outs[0][1] == [int(t) for t in ref[:2]]


def test_streaming_and_per_request_sampling_params():
    """Streaming callbacks fire once per token in emission order; requests
    with different temperature/top_k coexist in one pool and sampled
    requests are schedule-deterministic (same rid/seed -> same tokens)."""
    cfg, params = setup("hyena-153m")
    got = []
    eng = ServeEngine(params, cfg, SCFG, seed=7)
    r0 = eng.submit(np.array([3, 5, 7]), max_new_tokens=3,
                    stream=lambda rid, tok, done: got.append((rid, tok, done)))
    r1 = eng.submit(np.array([2, 4]), max_new_tokens=3, temperature=0.9,
                    top_k=8)
    out = eng.drain()
    assert [g[0] for g in got].count(r0) == 3
    assert got[-1][2] or any(d for _, _, d in got)
    assert [t for rid, t, _ in got if rid == r0] == [int(x) for x in out[r0]]
    # re-running the sampled request alone reproduces its tokens exactly
    eng2 = ServeEngine(params, cfg, SCFG, seed=7)
    eng2._next_rid = r1  # same rid -> same per-request key stream
    r1b = eng2.submit(np.array([2, 4]), max_new_tokens=3, temperature=0.9,
                      top_k=8)
    out2 = eng2.drain()
    assert [int(x) for x in out2[r1b]] == [int(x) for x in out[r1]]


def test_finished_requests_are_pruned_and_poppable():
    """A long-lived engine must not retain finished Request objects; the
    tokens remain retrievable until popped."""
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)
    rid = eng.submit(np.array([1, 2, 3]), max_new_tokens=2)
    out = eng.drain()
    assert rid not in eng._requests  # prompt/callback closure released
    toks = eng.pop_result(rid)
    assert list(toks) == [int(t) for t in out[rid]]
    assert rid not in eng.results()


def test_stream_callback_exception_keeps_state_consistent():
    """A raising stream callback must not desync tokens from caches: all
    bookkeeping lands before callbacks fire, so results() still returns
    the full reference output."""
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)

    def boom(rid, tok, done):
        raise RuntimeError("consumer bug")

    r0 = eng.submit(np.array([3, 5, 7, 2]), max_new_tokens=3, stream=boom)
    with pytest.raises(RuntimeError, match="consumer bug"):
        while not eng.scheduler.idle:
            eng.step()
    # recover: detach the broken callback and keep stepping
    if r0 in eng._requests:
        eng._requests[r0].stream = None
    out = eng.drain()
    ref = np.asarray(
        _reference(params, jnp.asarray([[3, 5, 7, 2]]), cfg=cfg)[0]
    )
    assert [int(t) for t in out[r0]] == [int(t) for t in ref[:3]]


def test_submit_validation():
    cfg, params = setup("hyena-153m")
    eng = ServeEngine(params, cfg, SCFG)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(MAX_LEN), max_new_tokens=1)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.array([1]), max_new_tokens=0)


# ---------------------------------------------------------------- paged
#
# The paged engine's randomized harness (reference construction, tie-aware
# comparison, plan generation) lives in tests/serve_parity.py so the
# distributed suite can drive the identical scenarios in its 8-device
# subprocesses; here we pin fixed seeds in the fast tier, run the full
# property sweep per mixer family in the slow tier, and unit-test the
# paged substrate (allocator, SLO queue, radix tree, COW, drain budgets).
import serve_parity
from repro.serve.engine import DrainExhausted, request_token_key
from repro.serve.paged import BlockAllocator, PagedConfig, PagedServeEngine
from repro.serve.radix import RadixPrefixCache
from repro.serve.sampling import sample_slots
from repro.serve.slo import SLOQueue

PCFG = PagedConfig(page_size=4)


def test_paged_schedule_fixed_seed():
    """Fast-tier pin: one fixed randomized paged schedule (prefix sharing,
    chunked prefill, eviction + radix chaos) on hyena, tie-aware
    token-identical to the sequential reference."""
    serve_parity.check_paged_schedule("hyena-153m", 1234)


def _make_paged_harness(arch):
    @prop.given(seed=prop.integers(0, 1 << 30))
    def harness(seed):
        serve_parity.check_paged_schedule(arch, seed)

    harness.__name__ = f"test_paged_randomized_{arch.replace('-', '_')}"
    return pytest.mark.slow(harness)


for _arch in HARNESS_ARCHS:
    _t = _make_paged_harness(_arch)
    globals()[_t.__name__] = _t
del _t


def test_paged_prefix_fork_restores_pinned_state():
    """Two staggered requests sharing an 10-token system prompt: the
    second forks the radix prefix (8 cached tokens at page 4) and both
    emit exactly the sequential reference — on hyena, whose cache mixes
    paged operand history with pinned short-conv windows and cursors, so
    a fork is only correct if the pinned snapshot is restored too."""
    cfg, params, _ = serve_parity.setup("hyena-153m")
    scfg = serve_parity.SCFG
    eng = PagedServeEngine(params, cfg, scfg, PCFG)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    r0 = eng.submit(shared, max_new_tokens=4)
    out = dict(eng.drain())  # r0 finishes; its prefix pages are inserted
    p1 = np.concatenate([shared, [5, 7]]).astype(np.int32)
    r1 = eng.submit(p1, max_new_tokens=4)
    out.update(eng.drain())
    assert eng.request_metrics[r1]["prefix_cached_tokens"] == 8
    for rid, prompt in ((r0, shared), (r1, p1)):
        ref = np.asarray(generate(
            params, cfg, jnp.asarray(prompt[None]), scfg=scfg,
            max_new_tokens=4,
        ))[0]
        assert [int(t) for t in out[rid]] == [int(t) for t in ref], rid
    eng.flush_prefix()
    eng.check_clean()


def test_paged_sampled_schedule_independent():
    """A sampled request's tokens depend only on (seed, rid, token index):
    forking a cached prefix vs prefilling from scratch yields the same
    stream."""
    cfg, params, _ = serve_parity.setup("hyena-153m")
    scfg = serve_parity.SCFG
    prompt = np.arange(1, 9, dtype=np.int32)
    outs = []
    for prefix_cache in (True, False):
        eng = PagedServeEngine(
            params, cfg, scfg,
            PagedConfig(page_size=4, prefix_cache=prefix_cache),
        )
        warm = eng.submit(prompt, max_new_tokens=2)
        for _ in range(4):
            eng.step()
        eng._next_rid = 17  # same rid -> same per-request key stream
        rid = eng.submit(prompt, max_new_tokens=4, temperature=0.9,
                         top_k=8)
        out = eng.drain()
        outs.append([int(t) for t in out[rid]])
        del warm
    assert outs[0] == outs[1], outs


def test_sampled_scores_reproduces_sample_slots():
    """The parity harness's reference reproduces sample_slots exactly: a
    sampled row's token is the argmax of the temperature-scaled, top-k
    masked, gumbel-perturbed logits under the same per-request key."""
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    temps = jnp.asarray([0.0, 0.8, 1.3, 0.5], jnp.float32)
    topks = jnp.asarray([0, 0, 8, 3], jnp.int32)
    base = jax.random.PRNGKey(0)
    keys = jnp.stack([
        request_token_key(base, jnp.asarray(r, jnp.int32),
                          jnp.asarray(2, jnp.int32))
        for r in range(4)
    ])
    got = sample_slots(keys, logits, temps, topks)
    for r in range(4):
        want = int(jnp.argmax(serve_parity.sampled_scores(
            keys[r], logits[r], float(temps[r]), int(topks[r]),
        )))
        assert int(got[r]) == want, r


def test_scheduler_readmission_beats_new_arrivals():
    """Starvation regression (dense engine): an evicted request re-enters
    AHEAD of queued arrivals — under a 1-slot pool with a backlog, FIFO
    requeue would park the victim behind every arrival forever."""
    cfg, params = setup("hyena-153m")
    scfg = dataclasses.replace(SCFG, n_slots=1)
    eng = ServeEngine(params, cfg, scfg)
    prompts = {
        "a": np.array([3, 5, 7, 2], np.int32),
        "b": np.array([4, 1, 6], np.int32),
        "c": np.array([2, 2, 9], np.int32),
    }
    ra = eng.submit(prompts["a"], max_new_tokens=6)
    eng.step()  # a resident (admission prefill + one decode: 2 tokens out)
    rb = eng.submit(prompts["b"], max_new_tokens=2)
    rc = eng.submit(prompts["c"], max_new_tokens=2)
    assert eng.evict(ra)
    assert [r.rid for r in eng.scheduler.readmit] == [ra]
    eng.step()
    resident = [r.rid for r in eng.scheduler.active.values()]
    assert resident == [ra], (
        f"evicted request lost its turn to a new arrival: {resident}"
    )
    out = eng.drain()
    for rid, key, n in ((ra, "a", 6), (rb, "b", 2), (rc, "c", 2)):
        ref = np.asarray(generate(
            params, cfg, jnp.asarray(prompts[key][None]), scfg=scfg,
            max_new_tokens=n,
        ))[0]
        assert [int(t) for t in out[rid]] == [int(t) for t in ref[:n]], key


@pytest.mark.parametrize("paged", [False, True])
def test_drain_budget_raises_with_partial_results(paged):
    """drain(max_steps) out of budget raises DrainExhausted carrying the
    partial rid -> tokens map and active rids; the engine stays
    consistent, so a follow-up drain finishes the work."""
    if paged:
        cfg, params, _ = serve_parity.setup("hyena-153m")
        eng = PagedServeEngine(params, cfg, serve_parity.SCFG, PCFG)
    else:
        cfg, params = setup("hyena-153m")
        eng = ServeEngine(params, cfg, SCFG)
    prompt = np.array([3, 5, 7, 2], np.int32)
    rid = eng.submit(prompt, max_new_tokens=4)
    with pytest.raises(DrainExhausted) as ei:
        eng.drain(max_steps=1)
    err = ei.value
    assert err.max_steps == 1 and err.active == (rid,)
    assert rid in err.partial and len(err.partial[rid]) < 4
    assert "still active" in str(err)
    out = eng.drain()  # resumes exactly where the budget cut off
    ref = np.asarray(generate(
        params, cfg, jnp.asarray(prompt[None]), scfg=serve_parity.SCFG,
        max_new_tokens=4,
    ))[0]
    assert [int(t) for t in out[rid]] == [int(t) for t in ref]


def test_cow_copies_shared_block_before_write():
    """_ensure_writable on a block whose refcount > 1 allocates a private
    copy, moves the slot's table entry, and preserves contents byte-for-
    byte — the safety net partial-page forks would rely on."""
    cfg, params, _ = serve_parity.setup("hyena-153m")
    eng = PagedServeEngine(params, cfg, serve_parity.SCFG, PCFG)
    b = eng.alloc.alloc()
    eng.alloc.incref(b)  # simulate a second owner (radix node / fork)
    marked = []
    for j, i in enumerate(eng.spec.paged_idx):
        s = eng.spec.slot_axes[i]
        idx = (slice(None),) * s + (b,)
        eng._phys[j] = eng._phys[j].at[idx].set(1.5)
        marked.append((j, s))
    eng._table[0, 0] = b
    assert eng._ensure_writable(0, 0, 1)
    nb = int(eng._table[0, 0])
    assert nb != b and nb != 0
    assert int(eng.alloc.ref[b]) == 1 and int(eng.alloc.ref[nb]) == 1
    for j, s in marked:
        src = np.asarray(jnp.take(eng._phys[j], b, axis=s), np.float32)
        dst = np.asarray(jnp.take(eng._phys[j], nb, axis=s), np.float32)
        np.testing.assert_array_equal(dst, src)
        assert float(np.abs(dst).sum()) > 0.0


def test_block_allocator_unit():
    alloc = BlockAllocator(4)
    assert alloc.n_free == 3  # block 0 is the reserved trash block
    a, b, c = alloc.alloc(), alloc.alloc(), alloc.alloc()
    assert (a, b, c) == (1, 2, 3) and alloc.alloc() is None
    alloc.incref(b)
    assert not alloc.decref(b) and alloc.n_free == 0
    assert alloc.decref(b) and alloc.n_free == 1
    assert alloc.alloc() == b  # freed block recycles
    with pytest.raises(ValueError):
        BlockAllocator(1)


def test_slo_queue_ordering_unit():
    """Admission order: readmits first, then priority (higher wins), then
    deadline (earlier wins), then arrival order."""
    q = SLOQueue()
    q.push(0, priority=0)
    q.push(1, priority=2)
    q.push(2, priority=2, deadline=5)
    q.push(3, priority=2, deadline=3)
    q.push(4, priority=0)
    assert q.peek() == (3, False) and q.peek_priority() == 2
    q.push_readmit(9)
    assert q.peek() == (9, True)
    assert q.peek_priority() == 2  # readmits never trigger preemption
    assert list(q.rids())[0] == 9
    assert [q.pop() for _ in range(len(q))] == [9, 3, 2, 1, 0, 4]
    q.push(5, priority=1)
    q.push(6, priority=1)
    assert q.remove(5) and not q.remove(5)
    assert q.pop() == 6 and q.pop() is None


def test_radix_prefix_cache_unit():
    alloc = BlockAllocator(8)
    radix = RadixPrefixCache(2, alloc)
    a, b = alloc.alloc(), alloc.alloc()
    with pytest.raises(ValueError, match="page-aligned"):
        radix.insert((1, 2, 3), [a, b], ["snap"])
    # the engine inserts at every page boundary as prefill advances, so
    # each node carries the snapshot taken when it was the frontier
    assert radix.insert((1, 2), [a], ["snap1"])
    assert radix.insert((1, 2, 3, 4), [a, b], ["snap2"])
    assert radix.n_nodes == 2
    assert int(alloc.ref[a]) == 2 and int(alloc.ref[b]) == 2
    # longest whole-page match, capped at len - 1 (a token must remain)
    depth, blocks, snap = radix.match((1, 2, 3, 4, 5))
    assert (depth, blocks, snap) == (4, [a, b], ["snap2"])
    assert radix.match((1, 2, 3, 4))[:1] == (2,)  # cap: limit = 3
    assert radix.match((9, 9, 9))[0] == 0
    # the donor finished: it drops its own refs, the tree keeps the blocks
    alloc.decref(a), alloc.decref(b)
    assert radix.evict_lru(1) == [b]  # leaf only; ref hit zero
    assert radix.n_nodes == 1 and alloc.n_free == 6
    assert radix.match((1, 2, 3, 4, 5))[:2] == (2, [a])
    assert radix.flush() == [a]
    assert radix.n_nodes == 0 and alloc.n_free == 7


def test_paged_submit_validation():
    cfg, params, _ = serve_parity.setup("hyena-153m")
    eng = PagedServeEngine(
        params, cfg, serve_parity.SCFG,
        PagedConfig(page_size=4, n_blocks=3),  # 2 usable = 8 tokens
    )
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(np.arange(8), max_new_tokens=4)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(MAX_LEN), max_new_tokens=1)
    eng.submit(np.arange(4), max_new_tokens=4)  # exactly 2 blocks: fits
    eng.drain()
