"""Resilient-training-loop tests (DESIGN.md §10).

The contract under test: a run preempted mid-training and resumed via
``TrainLoop`` produces a step-for-step identical loss trajectory — and
bitwise-identical final params — to an uninterrupted run; the loop owns
the whole checkpoint/telemetry lifecycle (no caller wiring); the int8
error-feedback gradient channel trains associative recall to the same
accuracy as uncompressed; and a checkpoint written on one topology
restores onto another through the rule engine (elastic re-mesh).
"""
import dataclasses
import json
import os
import shutil
import signal
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import lm_data, synthetic
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import ft
from repro.train import optim as O
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.trainer import TrainConfig, abstract_train_state, init_train_state

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def tiny_cfg(vocab=32):
    cfg = get_config("hyena-153m").reduced()
    return dataclasses.replace(cfg, vocab_size=vocab, n_layers=2, d_model=64)


def tiny_tcfg(steps=10, compression=None):
    return TrainConfig(
        optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=steps),
        remat=False,
        grad_compression=compression,
    )


def corpus_stream(cursor=0):
    corpus = np.arange(20_000, dtype=np.int32) % 31
    return lm_data.TokenStream(
        corpus, global_batch=4, seq_len=32, seed=7, cursor=cursor
    )


# ------------------------------------------------------- resume parity

@pytest.mark.parametrize("compression", [None, "int8_ef"])
def test_preempt_resume_trajectory_identical(tmp_path, compression):
    """Kill at a step boundary, restart from the committed checkpoint, and
    the loss trajectory (and final params) must be bit-identical to an
    uninterrupted run — train state, loader cursor, RNG key, and step all
    round-trip.  Exercises the stateful TokenStream path (the loop owns
    the Prefetcher and checkpoints the consumed-batch cursor)."""
    cfg, steps = tiny_cfg(), 8
    tcfg = tiny_tcfg(steps, compression)

    # uninterrupted reference
    loop_a = TrainLoop(cfg, tcfg, LoopConfig(total_steps=steps, log_every=99),
                       handler=ft.PreemptionHandler(signals=()))
    res_a = loop_a.run(corpus_stream(), key=jax.random.PRNGKey(0))
    assert res_a.status == "done" and len(res_a.history) == steps

    # preempted at step 4 + resumed
    d = str(tmp_path / "ck")
    lcfg = LoopConfig(total_steps=steps, ckpt_dir=d, ckpt_every=3,
                      log_every=99, heartbeat_interval=None)
    h = ft.PreemptionHandler(signals=())
    loop_b = TrainLoop(cfg, tcfg, lcfg, handler=h)
    res_b = loop_b.run(
        corpus_stream(), key=jax.random.PRNGKey(0),
        on_step=lambda step, m, dt: h.trigger() if step == 4 else None,
    )
    assert res_b.status == "preempted" and res_b.step == 4
    assert ckpt.latest_step(d) == 4  # drained to a committed boundary

    loop_c = TrainLoop(cfg, tcfg, lcfg, handler=ft.PreemptionHandler(signals=()))
    # a different key on resume must NOT fork the trajectory — the
    # checkpointed base key wins
    res_c = loop_c.run(corpus_stream(), key=jax.random.PRNGKey(123))
    assert res_c.status == "done"

    assert res_b.history + res_c.history == res_a.history
    for a, b in zip(jax.tree_util.tree_leaves(res_a.state),
                    jax.tree_util.tree_leaves(res_c.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loop_retention_and_meta(tmp_path):
    """The loop's retention policy keeps exactly keep_last committed steps,
    and the checkpoint meta carries the loader cursor + step."""
    cfg, steps = tiny_cfg(), 7
    d = str(tmp_path / "ck")
    lcfg = LoopConfig(total_steps=steps, ckpt_dir=d, ckpt_every=2,
                      keep_last=2, log_every=99, heartbeat_interval=None)
    loop = TrainLoop(cfg, tiny_tcfg(steps), lcfg,
                     handler=ft.PreemptionHandler(signals=()))
    loop.run(corpus_stream(), key=jax.random.PRNGKey(0))
    kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert kept == ["step_00000006", "step_00000007"]
    struct, _ = abstract_train_state(cfg, None)
    like = {"train": struct,
            "rng": jax.eval_shape(lambda: jax.random.PRNGKey(0))}
    _, meta, step = ckpt.restore(d, like)
    assert step == 7 and meta["step"] == 7
    assert meta["loader"]["cursor"] == 7  # consumed-batch cursor, not head


def test_stateless_source_rejects_stream_cursor(tmp_path):
    """A checkpoint written with a stream loader can't silently resume a
    stateless callable source (the cursor would be dropped)."""
    cfg = tiny_cfg()
    d = str(tmp_path / "ck")
    lcfg = LoopConfig(total_steps=4, ckpt_dir=d, ckpt_every=2, log_every=99,
                      heartbeat_interval=None)
    h = ft.PreemptionHandler(signals=())
    loop = TrainLoop(cfg, tiny_tcfg(4), lcfg, handler=h)
    loop.run(corpus_stream(), key=jax.random.PRNGKey(0),
             on_step=lambda step, m, dt: h.trigger() if step == 2 else None)
    batch = corpus_stream().next_batch()
    loop2 = TrainLoop(cfg, tiny_tcfg(4), lcfg,
                      handler=ft.PreemptionHandler(signals=()))
    with pytest.raises(ValueError, match="stateless"):
        loop2.run(lambda s, k: batch, key=jax.random.PRNGKey(0))


def test_stream_source_rejects_cursorless_checkpoint(tmp_path):
    """...and the opposite swap: a checkpoint written with a stateless
    source can't position a stream — resuming would replay from cursor 0."""
    cfg = tiny_cfg()
    d = str(tmp_path / "ck")
    lcfg = LoopConfig(total_steps=4, ckpt_dir=d, ckpt_every=2, log_every=99,
                      heartbeat_interval=None)
    h = ft.PreemptionHandler(signals=())
    loop = TrainLoop(cfg, tiny_tcfg(4), lcfg, handler=h)
    batch = corpus_stream().next_batch()
    loop.run(lambda s, k: batch, key=jax.random.PRNGKey(0),
             on_step=lambda step, m, dt: h.trigger() if step == 2 else None)
    loop2 = TrainLoop(cfg, tiny_tcfg(4), lcfg,
                      handler=ft.PreemptionHandler(signals=()))
    with pytest.raises(ValueError, match="cursor 0"):
        loop2.run(corpus_stream(), key=jax.random.PRNGKey(0))


# --------------------------------------------------------- compression

def test_compressed_step_carries_residuals():
    """grad_compression='int8_ef' is live: residuals appear in the train
    state (fp32, params-shaped), become nonzero after one step, checkpoint
    alongside everything else, and the step reports the channel error."""
    cfg = tiny_cfg()
    tcfg = tiny_tcfg(3, "int8_ef")
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    assert jax.tree_util.tree_structure(
        state["cgrad"]
    ) == jax.tree_util.tree_structure(state["params"])
    loop = TrainLoop(cfg, tcfg, LoopConfig(total_steps=3, log_every=99),
                     handler=ft.PreemptionHandler(signals=()))
    res = loop.run(corpus_stream(), key=jax.random.PRNGKey(0))
    assert "compression_abs_err" in res.metrics
    resid_max = max(
        float(np.abs(np.asarray(x)).max())
        for x in jax.tree_util.tree_leaves(res.state["cgrad"])
    )
    assert 0 < resid_max < 1.0  # error feedback carried, bounded
    assert res.history[-1] < res.history[0]


def test_invalid_grad_compression_rejected():
    with pytest.raises(ValueError, match="grad_compression"):
        TrainConfig(grad_compression="fp4")


@pytest.mark.slow
def test_compression_matches_uncompressed_recall_accuracy():
    """§4.1 convergence through the lossy channel: int8 error-feedback
    compression trains associative recall to the same accuracy threshold
    as uncompressed in the same budget.  (The bar is recall accuracy on
    the trained dictionaries — both modes saturate it at 1.0; held-out
    dictionary accuracy at this container scale sits near chance and is
    chaotic across compiled programs, so it is pinned by the system-level
    recall test, not here.)"""
    vocab = 12
    cfg = dataclasses.replace(
        get_config("hyena-153m").reduced(), vocab_size=16, n_layers=2
    )
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(
        rng, n=256, seq_len=32, vocab=vocab
    )
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    accs, final_loss = {}, {}
    for comp in (None, "int8_ef"):
        tcfg = TrainConfig(
            optimizer=O.AdamWConfig(lr=2e-3, warmup_steps=10,
                                    total_steps=200, weight_decay=0.0),
            remat=False, grad_compression=comp,
        )
        loop = TrainLoop(cfg, tcfg, LoopConfig(total_steps=200, log_every=999),
                         handler=ft.PreemptionHandler(signals=()))
        res = loop.run(lambda s, k: batch, key=jax.random.PRNGKey(0))
        logits, _ = lm.forward(res.state["params"], cfg, jnp.asarray(tokens))
        accs[comp] = synthetic.eval_accuracy(
            np.asarray(logits, np.float32), labels
        )
        final_loss[comp] = res.history[-1]
    assert accs[None] >= 0.95, (accs, final_loss)
    assert accs["int8_ef"] >= 0.95, (accs, final_loss)  # same threshold
    assert final_loss["int8_ef"] < 0.05, final_loss


# ------------------------------------------------- kill-and-resume (OS)

_CHILD = """
import dataclasses, json, sys, time
import jax, numpy as np
from repro.configs import get_config
from repro.data import lm_data
from repro.train import optim as O
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.trainer import TrainConfig

ckpt_dir, hist_path, delay = sys.argv[1], sys.argv[2], float(sys.argv[3])
reversible = sys.argv[4] == "1"
cfg = dataclasses.replace(get_config("hyena-153m").reduced(),
                          vocab_size=32, n_layers=2, d_model=64)
tcfg = TrainConfig(optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0,
                                           total_steps=20),
                   remat=False, reversible=reversible)
lcfg = LoopConfig(total_steps=20, ckpt_dir=ckpt_dir, ckpt_every=2,
                  log_every=999, heartbeat_interval=None)
corpus = np.arange(20_000, dtype=np.int32) % 31
stream = lm_data.TokenStream(corpus, global_batch=4, seq_len=32, seed=7)

def on_step(step, metrics, dt):
    print(f"STEP {step}", flush=True)
    time.sleep(delay)

loop = TrainLoop(cfg, tcfg, lcfg)  # real SIGTERM handler
res = loop.run(stream, key=jax.random.PRNGKey(0), on_step=on_step)
json.dump({"status": res.status, "step": res.step, "history": res.history},
          open(hist_path, "w"))
print("EXIT", res.status, flush=True)
"""


def _spawn_child(ckpt_dir, hist_path, delay, reversible=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.Popen(
        [sys.executable, "-c", _CHILD, ckpt_dir, hist_path, str(delay),
         "1" if reversible else "0"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
    )


@pytest.mark.slow
@pytest.mark.parametrize("reversible", [False, True])
def test_sigterm_kill_and_resume_matches_uninterrupted(tmp_path, reversible):
    """The real thing: SIGTERM a training process mid-run; it drains to a
    committed checkpoint and exits 0; a restarted process resumes and the
    combined loss trajectory is identical to a never-killed run.  Runs
    under both block substrates — the reversible dual-stream coupling
    checkpoints the same state tree, so kill/resume must be equally
    bit-stable with the flag on (DESIGN.md §15)."""
    ref_hist = str(tmp_path / "ref.json")
    proc = _spawn_child(str(tmp_path / "ck_ref"), ref_hist, 0.0, reversible)
    out, err = proc.communicate(timeout=600)
    assert proc.returncode == 0, err[-3000:]
    ref = json.load(open(ref_hist))
    assert ref["status"] == "done" and len(ref["history"]) == 20

    kill_hist = str(tmp_path / "k1.json")
    ck = str(tmp_path / "ck_kill")
    proc = _spawn_child(ck, kill_hist, 0.3, reversible)
    deadline = time.time() + 300
    seen = 0
    for line in proc.stdout:
        if line.startswith("STEP "):
            seen = int(line.split()[1])
            if seen >= 5:
                proc.send_signal(signal.SIGTERM)
                break
        assert time.time() < deadline
    out, err = proc.communicate(timeout=600)
    assert proc.returncode == 0, err[-3000:]
    first = json.load(open(kill_hist))
    assert first["status"] == "preempted"
    assert 0 < first["step"] < 20

    resume_hist = str(tmp_path / "k2.json")
    proc = _spawn_child(ck, resume_hist, 0.0, reversible)
    out, err = proc.communicate(timeout=600)
    assert proc.returncode == 0, err[-3000:]
    second = json.load(open(resume_hist))
    assert second["status"] == "done"
    assert first["history"] + second["history"] == ref["history"]


# ---------------------------------------------------- elastic re-mesh

def test_checkpoint_restores_onto_mesh(tmp_path):
    """A checkpoint written on one device restores onto a 2x4 mesh through
    ctx.train_state_shardings (leaves placed by rule — including the
    compression residuals) and continues to the same losses."""
    d = str(tmp_path / "ck")
    cfg, steps = tiny_cfg(), 4
    tcfg = tiny_tcfg(steps, "int8_ef")
    lcfg = LoopConfig(total_steps=steps, ckpt_dir=d, ckpt_every=2,
                      log_every=99, heartbeat_interval=None)
    h = ft.PreemptionHandler(signals=())
    loop = TrainLoop(cfg, tcfg, lcfg, handler=h)
    res1 = loop.run(corpus_stream(), key=jax.random.PRNGKey(0),
                    on_step=lambda step, m, dt: h.trigger() if step == 2 else None)
    assert res1.status == "preempted" and ckpt.latest_step(d) == 2
    # the mesh run resumes from a copy — the single-device reference
    # continuation below writes its own later checkpoints into `d`
    d_mesh = str(tmp_path / "ck_mesh")
    shutil.copytree(d, d_mesh)
    loop2 = TrainLoop(cfg, tcfg, lcfg, handler=ft.PreemptionHandler(signals=()))
    res_ref = loop2.run(corpus_stream(), key=jax.random.PRNGKey(0))

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = f"""
import dataclasses, json
import jax, numpy as np
from repro.configs import get_config
from repro.data import lm_data
from repro.train import optim as O
from repro.train import ft
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.trainer import TrainConfig

cfg = dataclasses.replace(get_config("hyena-153m").reduced(),
                          vocab_size=32, n_layers=2, d_model=64)
tcfg = TrainConfig(optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0,
                                           total_steps={steps}),
                   remat=False, grad_compression="int8_ef")
lcfg = LoopConfig(total_steps={steps}, ckpt_dir={d_mesh!r}, ckpt_every=2,
                  log_every=99, heartbeat_interval=None)
mesh = jax.make_mesh((2, 4), ("data", "model"))
corpus = np.arange(20_000, dtype=np.int32) % 31
stream = lm_data.TokenStream(corpus, global_batch=4, seq_len=32, seed=7,
                             cursor=0)
loop = TrainLoop(cfg, tcfg, lcfg, mesh=mesh,
                 handler=ft.PreemptionHandler(signals=()))
res = loop.run(stream, key=jax.random.PRNGKey(0))
print("HIST", json.dumps(res.history))
print("OK")
"""
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    hist = json.loads(
        [l for l in proc.stdout.splitlines() if l.startswith("HIST ")][0][5:]
    )
    # same steps resumed on a different topology: losses agree to SPMD
    # reduction tolerance (not bitwise — the all-reduce order differs)
    np.testing.assert_allclose(hist, res_ref.history, atol=2e-2)


# ------------------------------------------------------- loop plumbing

def test_loop_config_validation():
    with pytest.raises(ValueError):
        LoopConfig(total_steps=0)
    with pytest.raises(ValueError):
        LoopConfig(total_steps=5, keep_last=0)
    with pytest.raises(ValueError):
        LoopConfig(total_steps=5, ckpt_every=0)


def test_completed_run_is_a_noop_on_rerun(tmp_path):
    cfg, steps = tiny_cfg(), 3
    d = str(tmp_path / "ck")
    lcfg = LoopConfig(total_steps=steps, ckpt_dir=d, ckpt_every=99,
                      log_every=99, heartbeat_interval=None)
    loop = TrainLoop(cfg, tiny_tcfg(steps), lcfg,
                     handler=ft.PreemptionHandler(signals=()))
    res = loop.run(corpus_stream(), key=jax.random.PRNGKey(0))
    assert res.status == "done"
    loop2 = TrainLoop(cfg, tiny_tcfg(steps), lcfg,
                      handler=ft.PreemptionHandler(signals=()))
    res2 = loop2.run(corpus_stream(), key=jax.random.PRNGKey(0))
    assert res2.status == "done" and res2.step == steps
    assert res2.history == []  # nothing re-run
