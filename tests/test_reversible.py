"""Reversible-block training substrate tests (DESIGN.md §15).

What "grad parity" means here: the reversible dual-stream net is a
*different function* from the standard single-stream stack (the streams
diverge after the first coupling), so the contract under test is that the
reconstruct-and-recompute ``custom_vjp`` produces the same gradients as
plain autodiff of the *identical reversible wiring*
(``reversible.reference_vjp()``), per mixer, with and without ``cp_axis``.

Tolerance story: the forward primal is the same computation either way, so
losses agree to fp32 noise.  Gradients additionally carry the stream
*reconstruction* error ``(a + b) - b``, amplified by the inverse chain's
conditioning — at an O(1)-magnitude residual stream (embeddings scaled to
unit RMS, as in any trained model) fp32 parity lands near 1e-5 and the
tests pin 1e-3.  Under bf16 the streams still ride in fp32 (see
reversible.py), so the reconstructed stream rounds back to the
bit-identical bf16 branch input and bf16 parity is *tighter* than fp32
(exact on CPU; 5e-3 documented envelope).  At a *badly* conditioned point
(raw tiny-init embeddings, first-block gain ~100) fp32 parity degrades to
~1e-3 — that is inverse conditioning, not a VJP defect, and it is why the
suite evaluates at the well-scaled point.

Multi-device cases run in subprocesses with
``--xla_force_host_platform_device_count=8`` (same idiom as
test_cp_train.py) so the main process keeps seeing one device.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.policy import BF16, FP32
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.distributed.execution import ExecutionContext
from repro.models import lm
from repro.models import reversible as REV
from repro.train import ft
from repro.train import optim as O
from repro.train import trainer as T
from repro.train.loop import LoopConfig, TrainLoop

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


def small_cfg(mixer, **kw):
    base = dict(
        name=f"rev-{mixer}", family="test",
        n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, pattern=(mixer,), local_window=8,
        ssm_state=16, ssd_head_dim=16, rnn_width=32,
        hyena_filter_width=16, hyena_pos_dim=9,
        hyena_se_len=4, hyena_mr_support=8,
    )
    base.update(kw)
    return ModelConfig(**base)


def well_scaled_params(cfg, seed=0):
    """Init params, then scale the embedding table so the residual stream
    enters the stack at O(1) RMS — the well-conditioned point for checking
    the reconstruction VJP (see module docstring)."""
    state, axes = T.init_train_state(jax.random.PRNGKey(seed), cfg)
    params = state["params"]
    params["embed"]["table"] = params["embed"]["table"] * 16.0
    return params, axes


def grad_parity(cfg, tcfg, batch, params):
    """(dloss, worst per-leaf rel grad err) between the custom VJP and
    plain autodiff of the same reversible wiring."""
    ctx = tcfg.apply_context()
    loss = lambda p: T._loss(p, cfg, tcfg, ctx, batch)
    (l_cust, m), g_cust = jax.value_and_grad(loss, has_aux=True)(params)
    with REV.reference_vjp():
        (l_ref, _), g_ref = jax.value_and_grad(loss, has_aux=True)(params)
    worst = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(g_cust),
                    jax.tree_util.tree_leaves(g_ref)):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        scale = max(np.abs(b).max(), 1e-6)
        worst = max(worst, float(np.abs(a - b).max() / scale))
    return abs(float(l_cust) - float(l_ref)), worst, m


MIXERS = [
    "attention", "local_attention", "hyena", "ssd", "rglru",
    "hyena_se", "hyena_mr", "hyena_li",
]


# ------------------------------------------------- per-mixer VJP parity

@pytest.mark.parametrize("mixer", MIXERS)
def test_reversible_vjp_matches_autodiff_fp32(mixer):
    """All five base mixers + the SE/MR/LI hyena variants: the scan-level
    custom_vjp (invert → recompute → pull back) matches plain autodiff of
    the identical coupling at fp32."""
    cfg = small_cfg(mixer)
    params, _ = well_scaled_params(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 64),
    }
    tcfg = T.TrainConfig(remat=False, policy=FP32, reversible=True)
    dl, worst, _ = grad_parity(cfg, tcfg, batch, params)
    assert dl < 1e-5, f"{mixer}: dloss={dl:.2e}"
    assert worst < 1e-3, f"{mixer}: grad_rel={worst:.2e}"


def test_reversible_vjp_bf16_documented_tolerance():
    """bf16 envelope (documented in DESIGN.md §15): the dual streams ride
    in fp32, so the reconstructed stream re-rounds to the *bit-identical*
    bf16 branch input and recompute noise does not compound — in practice
    parity is exact on CPU; 5e-3 is the documented envelope (fusion-order
    differences across compilers may break bitwise identity)."""
    cfg = small_cfg("hyena")
    params, _ = well_scaled_params(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 64),
    }
    tcfg = T.TrainConfig(remat=False, policy=BF16, reversible=True)
    dl, worst, _ = grad_parity(cfg, tcfg, batch, params)
    assert dl < 1e-3, f"dloss={dl:.2e}"
    assert worst < 5e-3, f"grad_rel={worst:.2e}"


def test_reversible_vjp_moe_aux_losses_survive():
    """MoE channel mixers inside the coupling: router aux losses are scan
    outputs of the reversible forward and their cotangents feed the
    per-group recompute — parity must hold on *router* grads too, and the
    aux metrics must be live (nonzero) and equal across VJP modes."""
    cfg = small_cfg(
        "hyena", moe=True, n_experts=4, top_k=2, d_ff=64,
        pattern=("hyena", "attention"),
    )
    params, _ = well_scaled_params(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, 64),
    }
    tcfg = T.TrainConfig(remat=False, policy=FP32, reversible=True)
    dl, worst, metrics = grad_parity(cfg, tcfg, batch, params)
    assert dl < 1e-5, f"dloss={dl:.2e}"
    assert worst < 1e-3, f"grad_rel={worst:.2e}"
    assert float(metrics["moe_load_balance"]) > 0.0


def test_reversible_vjp_multihybrid_hyena_mh_small():
    """Acceptance row: the registry ``hyena-mh-small`` SE-MR-LI-attn
    pattern (reduced dims, full 4-way pattern) through the reversible path
    at fp32."""
    cfg = dataclasses.replace(
        get_config("hyena-mh-small").reduced(),
        vocab_size=64, hyena_se_len=4, hyena_mr_support=8,
    )
    assert cfg.pattern == ("hyena_se", "hyena_mr", "hyena_li", "attention")
    params, _ = well_scaled_params(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 64),
    }
    tcfg = T.TrainConfig(remat=False, policy=FP32, reversible=True)
    dl, worst, _ = grad_parity(cfg, tcfg, batch, params)
    assert dl < 1e-5, f"dloss={dl:.2e}"
    assert worst < 1e-3, f"grad_rel={worst:.2e}"


# --------------------------------------------------- e2e + composition

def test_reversible_full_train_step_composes():
    """End-to-end make_train_step on the reversible path: microbatches,
    MoE aux in the metrics, finite loss, params move.  remat=True is the
    TrainConfig default — the reversible branch must simply bypass it."""
    cfg = small_cfg(
        "hyena", moe=True, n_experts=4, top_k=2,
        pattern=("hyena", "attention"),
    )
    tcfg = T.TrainConfig(
        optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
        remat=True, policy=FP32, reversible=True, microbatches=2,
    )
    state, _ = T.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 64)
    step = T.jit_train_step(cfg, tcfg)
    p0 = np.asarray(jax.tree_util.tree_leaves(state["params"])[0]).copy()
    state, m = step(state, {"tokens": tok})
    state, m = step(state, {"tokens": tok})
    assert np.isfinite(float(m["loss"]))
    assert float(m["moe_load_balance"]) > 0.0
    p1 = np.asarray(jax.tree_util.tree_leaves(state["params"])[0])
    assert np.abs(p1 - p0).max() > 0


def test_reversible_rejects_unroll():
    with pytest.raises(ValueError, match="reversible"):
        ExecutionContext(reversible=True, unroll=True)
    with pytest.raises(ValueError, match="reversible"):
        T.TrainConfig(reversible=True, unroll=True).apply_context()


# ----------------------------------------------- inference invariance

def test_inference_path_ignores_reversible_flag():
    """Training-only transform: prefill logits, populated caches, decode
    logits, and ServeEngine completions are byte-identical whichever way
    the flag is set."""
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = small_cfg("hyena", pattern=("hyena", "attention"))
    state, _ = T.init_train_state(jax.random.PRNGKey(0), cfg)
    params = state["params"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)

    ctx_on = ExecutionContext(reversible=True, policy=FP32)
    ctx_off = ExecutionContext(policy=FP32)
    lg_on, caches_on = lm.prefill(
        params, cfg, prompts, 16, dtype=jnp.float32,
        compute_dtype=jnp.float32, ctx=ctx_on,
    )
    lg_off, caches_off = lm.prefill(
        params, cfg, prompts, 16, dtype=jnp.float32,
        compute_dtype=jnp.float32, ctx=ctx_off,
    )
    np.testing.assert_array_equal(np.asarray(lg_on), np.asarray(lg_off))
    for a, b in zip(jax.tree_util.tree_leaves(caches_on),
                    jax.tree_util.tree_leaves(caches_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tok = jnp.argmax(lg_on[:, -1], axis=-1).astype(jnp.int32)
    d_on, _ = lm.decode_step(params, cfg, tok, caches_on,
                             compute_dtype=jnp.float32, ctx=ctx_on)
    d_off, _ = lm.decode_step(params, cfg, tok, caches_off,
                              compute_dtype=jnp.float32, ctx=ctx_off)
    np.testing.assert_array_equal(np.asarray(d_on), np.asarray(d_off))

    # engine path: ectx with the flag set vs. the engine's own default
    # context — resolve_serve_context fills everything else identically
    scfg = ServeConfig(max_len=24, n_slots=2)
    outs = {}
    for name, ectx in (("on", ExecutionContext(reversible=True)),
                       ("off", None)):
        eng = ServeEngine(params, cfg, scfg, ectx=ectx)
        rid = eng.submit(np.asarray(prompts[0]), max_new_tokens=8)
        res = eng.drain()
        outs[name] = list(np.asarray(res[rid]))
    assert outs["on"] == outs["off"]


# --------------------------------------------- checkpoint compatibility

@pytest.mark.parametrize("first,second", [(False, True), (True, False)])
def test_checkpoint_flag_flip_restores_and_continues_bit_identically(
    tmp_path, first, second
):
    """A TrainLoop checkpoint written under one ``reversible`` setting
    restores under the other and continues exactly as a live in-memory
    continuation under that other setting would — param/opt trees are
    identical by construction (proven on the abstract state), so the flag
    is a pure execution choice, never a checkpoint-format choice."""
    cfg = dataclasses.replace(
        get_config("hyena-153m").reduced(),
        vocab_size=32, n_layers=2, d_model=64,
    )
    opt = O.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=6)
    tcfg_a = T.TrainConfig(optimizer=opt, remat=False, policy=FP32,
                           reversible=first)
    tcfg_b = dataclasses.replace(tcfg_a, reversible=second)

    # identical by construction — prove it on the abstract trees
    sa, axa = T.abstract_train_state(cfg, tcfg_a)
    sb, axb = T.abstract_train_state(cfg, tcfg_b)
    assert jax.tree_util.tree_structure(sa) == jax.tree_util.tree_structure(sb)
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert axa == axb

    tok = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 32))
    batch = {"tokens": jnp.asarray(tok)}
    data = lambda s, k: batch  # stateless source: flag flips can't be
    # confounded by loader cursors

    d = str(tmp_path / "ck")
    lcfg_a = LoopConfig(total_steps=4, ckpt_dir=d, ckpt_every=4,
                        log_every=99, heartbeat_interval=None)
    loop_a = TrainLoop(cfg, tcfg_a, lcfg_a,
                       handler=ft.PreemptionHandler(signals=()))
    res_a = loop_a.run(data, key=jax.random.PRNGKey(0))
    assert res_a.status == "done" and res_a.step == 4

    # continue from the on-disk checkpoint under the flipped flag
    lcfg_b = LoopConfig(total_steps=6, ckpt_dir=d, ckpt_every=4,
                        log_every=99, heartbeat_interval=None)
    loop_b = TrainLoop(cfg, tcfg_b, lcfg_b,
                       handler=ft.PreemptionHandler(signals=()))
    res_b = loop_b.run(data, key=jax.random.PRNGKey(0))
    assert res_b.status == "done" and len(res_b.history) == 2

    # reference: the same two steps from the *live* end-of-run-A state
    step_fn = T.jit_train_step(cfg, tcfg_b, donate=False)
    state = res_a.state
    ref_hist = []
    for _ in range(2):
        state, m = step_fn(state, batch)
        ref_hist.append(float(m["loss"]))
    assert res_b.history == ref_hist  # bitwise float equality
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(res_b.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ cp_axis parity

@pytest.mark.slow
def test_reversible_cp_matches_single_device_per_mixer():
    """Nightly matrix: for every mixer, loss AND grads of the reversible
    cp-sharded step (2x4 mesh, cp over 'model') match the single-device
    reversible step under FP32 — the dual-stream carry shards like the
    standard carry and the backward's inverse scan runs under the same
    mesh."""
    mixers = ["attention", "local_attention", "hyena", "ssd", "rglru",
              "hyena_se", "hyena_mr", "hyena_li"]
    out = run_subprocess(f"""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        def small_cfg(mixer):
            return ModelConfig(
                name=f"revcp-{{mixer}}", family="test",
                n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                d_ff=64, vocab_size=64, pattern=(mixer,), local_window=8,
                ssm_state=16, ssd_head_dim=16, rnn_width=32,
                hyena_filter_width=16, hyena_pos_dim=9,
                hyena_se_len=4, hyena_mr_support=8,
            )

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, L = 8, 32
        for mixer in {mixers!r}:
            cfg = small_cfg(mixer)
            tok = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, 64)
            lab = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, 64)
            batch = {{"tokens": tok, "labels": lab}}
            tcfg1 = T.TrainConfig(
                optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
                remat=False, policy=FP32, reversible=True)
            tcfg2 = dataclasses.replace(tcfg1, cp_axis="model")
            state, axes = T.init_train_state(jax.random.PRNGKey(0), cfg)
            params = state["params"]
            params["embed"]["table"] = params["embed"]["table"] * 16.0

            ctx1 = tcfg1.apply_context()
            (l1, _), g1 = jax.value_and_grad(
                lambda p, b: T._loss(p, cfg, tcfg1, ctx1, b),
                has_aux=True)(params, batch)

            ectx = tcfg2.apply_context(mesh=mesh)
            p2 = jax.device_put(params, ectx.param_shardings(axes, params))
            b2 = {{k: jax.device_put(
                      v, ectx.data_sharding(v.ndim, v.shape[0], v.shape[1]))
                  for k, v in batch.items()}}
            ctx2 = tcfg2.apply_context()
            with ectx.scope():
                (l2, _), g2 = jax.jit(jax.value_and_grad(
                    lambda p, b: T._loss(p, cfg, tcfg2, ctx2, b),
                    has_aux=True))(p2, b2)
                l2 = float(l2)
            dl = abs(float(l1) - l2)
            worst = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(g1),
                            jax.tree_util.tree_leaves(g2)):
                a = np.asarray(a, np.float32)
                b = np.asarray(jax.device_get(b), np.float32)
                scale = max(np.abs(a).max(), 1e-6)
                worst = max(worst, np.abs(a - b).max() / scale)
            assert dl < 1e-4, f"{{mixer}}: dloss={{dl:.2e}}"
            assert worst < 1e-3, f"{{mixer}}: grad_rel={{worst:.2e}}"
            print(f"{{mixer}} dloss={{dl:.2e}} grad_rel={{worst:.2e}} OK")
        print("REV-CP-MIXERS-OK")
    """)
    assert "REV-CP-MIXERS-OK" in out


@pytest.mark.slow
def test_reversible_cp8_multihybrid_and_moe():
    """Acceptance row: 8-way cp_axis runs of (a) the hyena-mh-small
    SE-MR-LI-attn pattern and (b) an MoE pattern, both through the
    reversible path, matching the single-device reversible step — and the
    MoE aux metrics agree, proving the scanned aux cotangent plumbing
    shards cleanly."""
    out = run_subprocess("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        cases = {
            "mh": ModelConfig(
                name="revcp-mh", family="test",
                n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                d_ff=64, vocab_size=64,
                pattern=("hyena_se", "hyena_mr", "hyena_li", "attention"),
                local_window=8, hyena_filter_width=16, hyena_pos_dim=9,
                hyena_se_len=4, hyena_mr_support=8,
            ),
            "moe": ModelConfig(
                name="revcp-moe", family="test",
                n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                d_ff=64, vocab_size=64, pattern=("hyena", "attention"),
                local_window=8, hyena_filter_width=16, hyena_pos_dim=9,
                moe=True, n_experts=4, top_k=2,
            ),
        }
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        B, L = 4, 64
        for name, cfg in cases.items():
            tok = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, 64)
            lab = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, 64)
            batch = {"tokens": tok, "labels": lab}
            tcfg1 = T.TrainConfig(
                optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
                remat=False, policy=FP32, reversible=True)
            tcfg2 = dataclasses.replace(tcfg1, cp_axis="model")
            state, axes = T.init_train_state(jax.random.PRNGKey(0), cfg)
            params = state["params"]
            params["embed"]["table"] = params["embed"]["table"] * 16.0

            ctx1 = tcfg1.apply_context()
            (l1, m1), g1 = jax.value_and_grad(
                lambda p, b: T._loss(p, cfg, tcfg1, ctx1, b),
                has_aux=True)(params, batch)

            ectx = tcfg2.apply_context(mesh=mesh)
            p2 = jax.device_put(params, ectx.param_shardings(axes, params))
            b2 = {k: jax.device_put(
                      v, ectx.data_sharding(v.ndim, v.shape[0], v.shape[1]))
                  for k, v in batch.items()}
            ctx2 = tcfg2.apply_context()
            with ectx.scope():
                (l2, m2), g2 = jax.jit(jax.value_and_grad(
                    lambda p, b: T._loss(p, cfg, tcfg2, ctx2, b),
                    has_aux=True))(p2, b2)
                l2 = float(l2)
                m2 = {k: float(v) for k, v in m2.items()}
            dl = abs(float(l1) - l2)
            worst = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(g1),
                            jax.tree_util.tree_leaves(g2)):
                a = np.asarray(a, np.float32)
                b = np.asarray(jax.device_get(b), np.float32)
                scale = max(np.abs(a).max(), 1e-6)
                worst = max(worst, np.abs(a - b).max() / scale)
            assert dl < 1e-4, f"{name}: dloss={dl:.2e}"
            assert worst < 1e-3, f"{name}: grad_rel={worst:.2e}"
            if name == "moe":
                assert m2["moe_load_balance"] > 0.0
                assert abs(m2["moe_load_balance"]
                           - float(m1["moe_load_balance"])) < 1e-4
            print(f"{name} dloss={dl:.2e} grad_rel={worst:.2e} OK")
        print("REV-CP8-OK")
    """)
    assert "REV-CP8-OK" in out


# ------------------------------------------------------ memory evidence

@pytest.mark.slow
def test_reversible_peak_memory_below_standard_at_depth():
    """The point of the substrate: at depth 16 the reversible step's XLA
    buffer-assignment peak (temp bytes) undercuts the standard remat step
    at the same config — depth-resident saves are gone."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        cfg = ModelConfig(
            name="rev-peak", family="test",
            n_layers=16, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=128, pattern=("hyena", "attention"),
            local_window=32, hyena_filter_width=16, hyena_pos_dim=9,
        )
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 2048), 0, 128)
        opt = O.AdamWConfig(lr=1e-3, warmup_steps=0)

        def peak(tcfg):
            state, _ = T.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
            step = jax.jit(T.make_train_step(cfg, tcfg))
            compiled = step.lower(state, {"tokens": tok}).compile()
            return int(compiled.memory_analysis().temp_size_in_bytes)

        p_std = peak(T.TrainConfig(optimizer=opt, remat=True, policy=FP32))
        p_rev = peak(T.TrainConfig(optimizer=opt, remat=True, policy=FP32,
                                   reversible=True))
        print(f"peak standard={p_std} reversible={p_rev}"
              f" ratio={p_std/max(p_rev,1):.2f}")
        assert p_rev < p_std, (p_rev, p_std)
        print("REV-PEAK-OK")
    """, devices=1)
    assert "REV-PEAK-OK" in out
