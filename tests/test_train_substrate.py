"""Optimizer / trainer / checkpoint / data-pipeline / FT tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import prop
from repro.configs import get_config
from repro.data import lm_data, synthetic, tokenizer
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import ft
from repro.train import optim as O
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


# ---------------------------------------------------------------- optimizer

def test_adamw_matches_hand_math():
    """One AdamW step on a scalar parameter vs hand-computed update."""
    cfg = O.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                        clip_norm=None, warmup_steps=0, schedule="constant")
    p = {"w": jnp.full((2, 2), 2.0)}
    g = {"w": jnp.full((2, 2), 0.5)}
    st = O.init_adamw(p)
    new_p, st, _ = O.adamw_update(cfg, g, st, p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = 2.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(new_p["w"], expect, rtol=1e-6)


def test_weight_decay_skips_1d():
    cfg = O.AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None,
                        warmup_steps=0, schedule="constant")
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    st = O.init_adamw(p)
    new_p, _, _ = O.adamw_update(cfg, g, st, p)
    assert float(jnp.abs(new_p["w"] - 1.0).max()) > 0  # decayed
    np.testing.assert_allclose(new_p["b"], 1.0)  # not decayed


def test_clip_norm():
    cfg = O.AdamWConfig(clip_norm=1.0, warmup_steps=0, schedule="constant")
    g = {"w": jnp.full((10,), 100.0)}
    gnorm = O.global_norm(g)
    assert float(gnorm) > 1.0


def test_schedule_shapes():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
    lrs = [float(O.schedule_lr(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-3


# ---------------------------------------------------------------- trainer

def test_loss_decreases_on_recall():
    """End-to-end: a tiny Hyena LM learns associative recall (paper §4.1)."""
    cfg = get_config("hyena-153m").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=32, n_layers=2)
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(rng, n=64, seq_len=32, vocab=16)
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60,
                                weight_decay=0.0),
        remat=False,
    )
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    losses = []
    for i in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::5]


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation: mean of 2 microbatch grads == full-batch grad.

    (Comparing *gradients*, not post-Adam params: Adam's first step is
    ±lr·sign(g), so near-zero grads amplify bf16 noise into sign flips.)
    """
    cfg = get_config("hyena-153m").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=32, n_layers=2)
    rng = np.random.default_rng(1)
    tokens, labels = synthetic.majority(rng, n=8, seq_len=16, vocab=8)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    p = state["params"]
    from repro.models.mixer_api import ApplyContext
    loss = lambda p, t, l: lm.loss_fn(p, cfg, t, l, ctx=ApplyContext())[0]
    g_full = jax.grad(loss)(p, jnp.asarray(tokens), jnp.asarray(labels))
    g_a = jax.grad(loss)(p, jnp.asarray(tokens[:4]), jnp.asarray(labels[:4]))
    g_b = jax.grad(loss)(p, jnp.asarray(tokens[4:]), jnp.asarray(labels[4:]))
    g_acc = jax.tree_util.tree_map(lambda a, b: (a + b) / 2.0, g_a, g_b)
    for x, y in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        x, y = np.asarray(x, np.float32), np.asarray(y, np.float32)
        denom = max(np.abs(x).max(), np.abs(y).max(), 1e-3)
        assert np.abs(x - y).max() / denom < 3e-2


# -------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": [{"b": jnp.ones((4,), jnp.bfloat16)}, jnp.zeros((), jnp.int32)],
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree, meta={"note": "x"})
    like = jax.tree_util.tree_map(lambda x: x, tree)
    restored, meta, step = ckpt.restore(d, like)
    assert step == 7 and meta["note"] == "x"
    for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_checkpoint_uncommitted_ignored(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((2,))}
    ckpt.save(d, 1, tree)
    # fake a crashed (uncommitted) later step
    os.makedirs(os.path.join(d, "step_00000002"))
    assert ckpt.latest_step(d) == 1


def test_checkpoint_integrity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((8,))}
    path = ckpt.save(d, 3, tree)
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, fn))
    np.save(os.path.join(path, fn), arr + 1)  # corrupt
    with pytest.raises(IOError):
        ckpt.restore(d, tree)


def test_cleanup_retention_explicit(tmp_path):
    """keep_last=0 must refuse instead of deleting every checkpoint
    (including the newest — the only restart point a preempted run has)."""
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((2,))}
    for s in [1, 2, 3]:
        ckpt.save(d, s, tree)
    with pytest.raises(ValueError, match="keep_last"):
        ckpt.cleanup(d, keep_last=0)
    assert ckpt.latest_step(d) == 3  # nothing was deleted
    ckpt.cleanup(d, keep_last=1)
    assert ckpt.latest_step(d) == 3
    assert sorted(os.listdir(d)) == ["step_00000003"]


def test_cleanup_ignores_uncommitted_for_retention(tmp_path):
    """Crash debris (an uncommitted step dir) neither counts toward
    retention nor survives it."""
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((2,))}
    ckpt.save(d, 1, tree)
    ckpt.save(d, 2, tree)
    os.makedirs(os.path.join(d, "step_00000003"))  # crashed, no _COMMITTED
    ckpt.cleanup(d, keep_last=2)
    assert sorted(os.listdir(d)) == ["step_00000001", "step_00000002"]


def test_restore_sharding_structure_mismatch_raises(tmp_path):
    """A shardings tree whose structure differs from the target must raise
    with the offending key, not silently mis-pair leaves."""
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((2,)), "b": {"c": jnp.ones((3,))}}
    ckpt.save(d, 1, tree)
    bad = {"a": None, "b": {"WRONG": None}}
    with pytest.raises(ValueError, match="b~c|WRONG"):
        ckpt.restore(d, tree, shardings=bad)
    # too few leaves is just as wrong
    with pytest.raises(ValueError, match="shardings"):
        ckpt.restore(d, tree, shardings={"a": None})
    # an exactly-mirroring tree (None = default placement) still works
    restored, _, _ = ckpt.restore(d, tree, shardings={"a": None, "b": {"c": None}})
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones((2,)))


def test_async_checkpointer_error_not_latched_forever(tmp_path, monkeypatch):
    """One failed background write surfaces exactly once; later saves (and
    close) proceed — and close never leaks the worker thread."""
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(d, keep_last=2)
    tree = {"a": jnp.ones((2,))}
    real_save = ckpt.save
    boom = {"on": True}

    def flaky_save(directory, step, t, meta=None):
        if boom["on"]:
            raise IOError("disk full")
        return real_save(directory, step, t, meta)

    monkeypatch.setattr(ckpt, "save", flaky_save)
    ac.save(1, tree)
    with pytest.raises(IOError, match="disk full"):
        ac.wait()
    boom["on"] = False
    ac.save(2, tree)  # must NOT re-raise the stale error
    ac.wait()
    ac.close()
    assert not ac._thread.is_alive()
    assert ckpt.latest_step(d) == 2


def test_async_checkpointer_close_joins_after_error(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(d, keep_last=2)
    monkeypatch.setattr(
        ckpt, "save", lambda *a, **k: (_ for _ in ()).throw(IOError("boom"))
    )
    ac.save(1, {"a": jnp.ones((2,))})
    with pytest.raises(IOError):
        ac.close()
    # the shutdown sentinel still went through: no leaked worker
    ac._thread.join(timeout=5)
    assert not ac._thread.is_alive()


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(d, keep_last=2)
    tree = {"a": jnp.ones((4,))}
    for s in [1, 2, 3]:
        ac.save(s, tree, meta={"s": s})
    ac.close()
    assert ckpt.latest_step(d) == 3
    steps = sorted(os.listdir(d))
    assert "step_00000001" not in steps  # cleaned up


def test_checkpoint_train_state_resume(tmp_path):
    """Save mid-training, restore, and verify identical continuation."""
    cfg = get_config("hyena-153m").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=32, n_layers=2)
    rng = np.random.default_rng(2)
    tokens, labels = synthetic.counting(rng, n=8, seq_len=16, vocab=8)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    tcfg = TrainConfig(optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
                       remat=False)
    step = jax.jit(make_train_step(cfg, tcfg))
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    state, _ = step(state, batch)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, state)
    restored, _, _ = ckpt.restore(d, state)
    s_a, _ = step(state, batch)
    s_b, _ = step(restored, batch)
    for x, y in zip(jax.tree_util.tree_leaves(s_a["params"]),
                    jax.tree_util.tree_leaves(s_b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------------------------------------------------------- data

def test_loader_deterministic_and_resumable():
    corpus = np.arange(10_000, dtype=np.int32) % 255
    mk = lambda cur: lm_data.TokenStream(
        corpus, global_batch=4, seq_len=16, cursor=cur, seed=3
    )
    s1 = mk(0)
    batches = [s1.next_batch() for _ in range(5)]
    s2 = mk(0)
    for _ in range(3):
        s2.next_batch()
    state = s2.state()
    s3 = mk(0)
    s3.restore(state)
    np.testing.assert_array_equal(s3.next_batch()["tokens"], batches[3]["tokens"])


def test_loader_host_sharding_partitions_batch():
    corpus = np.arange(10_000, dtype=np.int32) % 255
    full = lm_data.TokenStream(corpus, global_batch=4, seq_len=16, seed=1)
    h0 = lm_data.TokenStream(corpus, global_batch=4, seq_len=16, seed=1,
                             host_id=0, n_hosts=2)
    h1 = lm_data.TokenStream(corpus, global_batch=4, seq_len=16, seed=1,
                             host_id=1, n_hosts=2)
    b = full.next_batch()["tokens"]
    b0 = h0.next_batch()["tokens"]
    b1 = h1.next_batch()["tokens"]
    np.testing.assert_array_equal(np.concatenate([b0, b1]), b)


def test_labels_are_next_tokens():
    corpus = np.arange(1000, dtype=np.int32) % 255
    s = lm_data.TokenStream(corpus, global_batch=2, seq_len=8,
                            shuffle_windows=False, seed=0)
    b = s.next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_prefetcher_consumed_state():
    corpus = np.arange(10_000, dtype=np.int32) % 255
    s = lm_data.TokenStream(corpus, global_batch=2, seq_len=16, seed=5)
    pf = lm_data.Prefetcher(s, depth=2)
    b1 = pf.next()
    st = pf.consumed_state
    assert st["cursor"] == 1
    pf.close()


def test_tokenizer_roundtrip():
    text = "Hyena hierarchy — attention-free!"
    ids = tokenizer.encode(text)
    assert tokenizer.decode(ids) == text


# ------------------------------------------------------------- synthetics

@prop.given(vocab=prop.integers(8, 40), seq_pow=prop.integers(3, 6))
def test_recall_labels_consistent(vocab, seq_pow):
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(
        rng, n=4, seq_len=2 ** seq_pow, vocab=vocab
    )
    mask = labels != synthetic.IGNORE
    assert mask.sum() == 4  # one supervised position per sequence
    # the label equals the token that follows the supervised position
    i, j = np.nonzero(mask)
    np.testing.assert_array_equal(labels[i, j], tokens[i, j + 1])


def test_addition_digits():
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.addition(rng, n=8, n_digits=3)
    a = tokens[:, 0] * 100 + tokens[:, 1] * 10 + tokens[:, 2]
    b = tokens[:, 3] * 100 + tokens[:, 4] * 10 + tokens[:, 5]
    s = (
        tokens[:, 6] * 1000 + tokens[:, 7] * 100 + tokens[:, 8] * 10 + tokens[:, 9]
    )
    np.testing.assert_array_equal(a + b, s)


# --------------------------------------------------------------------- FT

def test_straggler_monitor():
    m = ft.StragglerMonitor(threshold=2.0)
    for _ in range(10):
        m.record(0, 1.0)
    assert m.record(11, 5.0) is True
    assert m.stragglers == 1


def test_preemption_flag():
    h = ft.PreemptionHandler(signals=())
    assert not h.preempted()
    h.trigger()
    assert h.preempted()


def test_retry_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise IOError("transient")
        return 42

    assert ft.retry(flaky, attempts=5, base_delay=0.001) == 42


def test_retry_rejects_zero_attempts():
    """attempts=0 used to return None without ever calling fn — a mis-typed
    budget silently skipped the checkpoint write."""
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        return 1

    with pytest.raises(ValueError, match="attempts"):
        ft.retry(fn, attempts=0)
    assert calls["n"] == 0


def test_heartbeat_stop_joins_and_restarts(tmp_path):
    """stop() joins the beat thread (no write can race a teardown), and a
    stopped heartbeat can start again."""
    path = str(tmp_path / "hb")
    hb = ft.Heartbeat(path, interval=0.05)
    hb.start()
    with pytest.raises(RuntimeError):
        hb.start()  # double-start is a bug, not a silent no-op
    time.sleep(0.12)
    hb.stop()
    assert hb._thread is None  # joined
    os.remove(path)
    hb.start()  # restart: fresh thread + event
    hb.stop()
    assert os.path.exists(path)  # start() beats immediately


def test_heartbeat_beat_is_atomic(tmp_path, monkeypatch):
    """A monitor polling the liveness file must never read a torn/empty
    beat: the timestamp lands in a tmp file first and os.replace swaps it
    in, so a crash mid-beat leaves the previous beat intact."""
    path = tmp_path / "hb"
    hb = ft.Heartbeat(str(path), interval=99.0)
    hb.beat()
    v1 = float(path.read_text())  # full, parseable beat
    # crash between the tmp write and the swap: the visible file must
    # still hold the previous (complete) beat, not a partial write
    def boom(src, dst):
        raise OSError("simulated crash mid-beat")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="mid-beat"):
        hb.beat()
    assert float(path.read_text()) == v1
    monkeypatch.undo()
    hb.beat()
    assert float(path.read_text()) >= v1
    # no tmp debris after a successful beat
    assert [p.name for p in tmp_path.iterdir()] == ["hb"]
