"""Autotune plan-cache round trip (DESIGN.md §8): search → persist → load →
same plan, and the planned kernel output equals the default-tile output
(plans are semantics-preserving by construction).
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.conv_api import get_conv_backend
from repro.kernels import ops


@pytest.fixture
def plan_env(tmp_path, monkeypatch):
    path = tmp_path / "plans.json"
    monkeypatch.setenv(autotune.ENV_FILE, str(path))
    monkeypatch.setenv(autotune.ENV_MODE, "search")
    autotune.reset_cache()
    yield path
    autotune.reset_cache()


def _set_mode(monkeypatch, mode):
    monkeypatch.setenv(autotune.ENV_MODE, mode)
    autotune.reset_cache()  # simulate a fresh process reading the file


def test_plan_roundtrip_short_conv(plan_env, monkeypatch):
    B, L, D, K = 2, 64, 16, 3
    u = jnp.asarray(np.random.default_rng(0).standard_normal((B, L, D)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).standard_normal((D, K)),
                    jnp.float32)
    y_search = ops.short_conv_gate(u, w, use_kernel=True)

    plans = json.loads(plan_env.read_text())
    key = autotune.plan_key(f"short_conv_k{K}", (B, L, D), jnp.float32)
    assert key in plans
    plan = plans[key]
    assert set(plan) == {"block_l", "block_d"}

    # load mode (fresh in-memory cache) returns the persisted plan — and
    # never times candidates
    _set_mode(monkeypatch, "load")
    loaded = autotune.plan_for(
        f"short_conv_k{K}", (B, L, D), jnp.float32,
        candidates=[{"block_l": 1, "block_d": 1}],
        run=lambda **kw: (_ for _ in ()).throw(AssertionError("searched")),
    )
    assert loaded == plan

    # plan output == default-tile output
    y_load = ops.short_conv_gate(u, w, use_kernel=True)
    _set_mode(monkeypatch, "off")
    y_off = ops.short_conv_gate(u, w, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(y_search), np.asarray(y_load))
    np.testing.assert_allclose(
        np.asarray(y_load), np.asarray(y_off), rtol=1e-6, atol=1e-6
    )


def test_plan_roundtrip_toeplitz_and_blockfft(plan_env, monkeypatch):
    B, L, D = 2, 48, 8
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((D, L)) / L, jnp.float32)
    gate = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)

    y_t = ops.toeplitz_conv(u, h, None, gate, use_kernel=True)
    y_b = get_conv_backend("blockfft")(u, h, None, gate)

    plans = json.loads(plan_env.read_text())
    kt = autotune.plan_key("toeplitz_gated", (B, L, D), jnp.float32)
    kb = autotune.plan_key("blockfft", (B, L, D), jnp.float32)
    assert kt in plans and kb in plans
    R, S = plans[kb]["factors"]
    from repro.core.fftconv import next_fast_len
    assert R * S == next_fast_len(2 * L - 1)

    _set_mode(monkeypatch, "load")
    y_t2 = ops.toeplitz_conv(u, h, None, gate, use_kernel=True)
    y_b2 = get_conv_backend("blockfft")(u, h, None, gate)
    _set_mode(monkeypatch, "off")
    y_t0 = ops.toeplitz_conv(u, h, None, gate, use_kernel=True)
    y_b0 = get_conv_backend("blockfft")(u, h, None, gate)
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_t2))
    np.testing.assert_allclose(
        np.asarray(y_t2), np.asarray(y_t0), rtol=1e-6, atol=1e-6
    )
    # a different (valid) factor split reassociates the DFT sums — allclose,
    # not bit-equal
    np.testing.assert_allclose(
        np.asarray(y_b2), np.asarray(y_b0), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(y_b), np.asarray(y_b2), rtol=1e-6, atol=1e-6
    )


def test_plan_roundtrip_twolevel(plan_env, monkeypatch):
    """The ``"twolevel"`` plan kind (overlapped two-level FFT conv,
    DESIGN.md §14): search through the registered ``blockfft_overlap``
    backend persists a {factors, overlap, block_d} plan whose (R, S)
    split multiplies to the padded length; load returns it without
    searching; planned output matches the off-mode default schedule."""
    B, L, D = 1, 64, 8
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((D, L)) / L, jnp.float32)
    gate = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32)

    y_search = get_conv_backend("blockfft_overlap")(u, h, None, gate)

    plans = json.loads(plan_env.read_text())
    key = autotune.plan_key("twolevel", (B, L, D), jnp.float32)
    assert key in plans, sorted(plans)
    plan = plans[key]
    assert set(plan) == {"factors", "overlap", "block_d"}, plan
    R, S = plan["factors"]
    from repro.core.fftconv import next_fast_len
    assert R * S == next_fast_len(2 * L - 1), plan
    assert plan["overlap"] >= 1 and plan["block_d"] >= 1

    # load mode (fresh in-memory cache) reuses the persisted plan
    _set_mode(monkeypatch, "load")
    loaded = autotune.plan_for(
        "twolevel", (B, L, D), jnp.float32,
        candidates=[{"factors": [2, 2], "overlap": 1, "block_d": 1}],
        run=lambda **kw: (_ for _ in ()).throw(AssertionError("searched")),
    )
    assert loaded == plan
    y_load = get_conv_backend("blockfft_overlap")(u, h, None, gate)
    _set_mode(monkeypatch, "off")
    y_off = get_conv_backend("blockfft_overlap")(u, h, None, gate)
    np.testing.assert_array_equal(np.asarray(y_search), np.asarray(y_load))
    # a different factor split reassociates the DFT sums — allclose
    np.testing.assert_allclose(
        np.asarray(y_load), np.asarray(y_off), rtol=1e-4, atol=1e-4
    )


def test_load_mode_never_searches(plan_env, monkeypatch):
    _set_mode(monkeypatch, "load")

    def boom(**kw):
        raise AssertionError("load mode must not time candidates")

    got = autotune.plan_for(
        "short_conv", (1, 32, 8), jnp.float32,
        candidates=[{"block_l": 32, "block_d": 8}], run=boom,
    )
    assert got is None  # missing entry → kernel defaults, no search


def test_off_mode_is_inert(plan_env, monkeypatch):
    _set_mode(monkeypatch, "off")
    got = autotune.plan_for(
        "short_conv", (1, 32, 8), jnp.float32,
        candidates=[{"block_l": 32, "block_d": 8}],
        run=lambda **kw: (_ for _ in ()).throw(AssertionError("ran")),
    )
    assert got is None
    assert not os.path.exists(plan_env)


def test_schema_drifted_plan_falls_back_to_defaults(plan_env, monkeypatch):
    """A valid-JSON plan whose params the kernel doesn't know (renamed key,
    hand edit) must degrade to kernel defaults, not TypeError on the first
    request of that shape — load mode is serving-safe."""
    key = autotune.plan_key("short_conv_k3", (1, 32, 8), jnp.float32)
    plan_env.write_text(json.dumps({key: {"block_rows": 99}}))
    _set_mode(monkeypatch, "load")
    got = autotune.plan_for(
        "short_conv_k3", (1, 32, 8), jnp.float32,
        candidates=[{"block_l": 32, "block_d": 8}], run=lambda **kw: None,
    )
    assert got is None


def test_persist_merges_concurrent_writers(plan_env, monkeypatch):
    """A search must not clobber keys another process persisted after this
    process loaded its in-memory mirror (merge-then-replace, per-key
    last-writer-wins)."""
    _set_mode(monkeypatch, "search")
    autotune.plan_for(
        "a", (1, 2, 3), jnp.float32,
        candidates=[{"x": 1}], run=lambda **kw: None,
    )
    plans = json.loads(plan_env.read_text())
    plans["other-process:key"] = {"y": 2}  # external writer, behind our back
    plan_env.write_text(json.dumps(plans))
    autotune.plan_for(
        "b", (1, 2, 3), jnp.float32,
        candidates=[{"z": 3}], run=lambda **kw: None,
    )
    final = json.loads(plan_env.read_text())
    assert "other-process:key" in final
    assert autotune.plan_key("a", (1, 2, 3), jnp.float32) in final
    assert autotune.plan_key("b", (1, 2, 3), jnp.float32) in final


def test_load_mode_picks_up_plan_file_written_later(plan_env, monkeypatch):
    """A load-mode consumer must see plans an offline searcher writes AFTER
    the consumer's first (missing) lookup — no restart required (the
    in-memory mirror is keyed by the file's stat signature)."""
    _set_mode(monkeypatch, "load")
    kwargs = dict(
        candidates=[{"block_l": 32, "block_d": 8}], run=lambda **kw: None
    )
    assert autotune.plan_for(
        "short_conv_k3", (1, 32, 8), jnp.float32, **kwargs
    ) is None
    key = autotune.plan_key("short_conv_k3", (1, 32, 8), jnp.float32)
    plan_env.write_text(json.dumps({key: {"block_l": 32, "block_d": 8}}))
    got = autotune.plan_for(
        "short_conv_k3", (1, 32, 8), jnp.float32, **kwargs
    )
    assert got == {"block_l": 32, "block_d": 8}


def test_corrupt_plan_file_is_empty(plan_env, monkeypatch):
    plan_env.write_text("{not json")
    _set_mode(monkeypatch, "load")
    got = autotune.plan_for(
        "short_conv", (1, 32, 8), jnp.float32,
        candidates=[{"block_l": 32, "block_d": 8}], run=lambda **kw: None,
    )
    assert got is None


def test_bad_mode_raises(monkeypatch):
    monkeypatch.setenv(autotune.ENV_MODE, "always")
    with pytest.raises(ValueError, match="REPRO_AUTOTUNE"):
        autotune.mode()
