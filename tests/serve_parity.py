"""Shared driver for the mesh-vs-single-device serve parity harness.

Imported by tests/test_serve_distributed.py *inside its 8-device
subprocesses* (PYTHONPATH carries both src/ and tests/): runs one
randomized continuous-batching schedule — arrivals, mixed prompt lengths,
horizons, stop tokens, preemptions — through TWO engines built from the
same params, one meshless and one mesh-native on a 2×4 debug mesh, and
asserts the emitted token streams are identical request-for-request
(DESIGN.md §9: mesh-native serving changes the layout, never the tokens).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import split_params
from repro.configs import get_config
from repro.distributed.execution import ExecutionContext
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine

MAX_LEN = 24
H_MAX = 4
SCFG = ServeConfig(max_len=MAX_LEN, temperature=0.0, n_slots=2,
                   cache_dtype=jnp.float32)


@functools.lru_cache(maxsize=None)
def setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    params, axes = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    return cfg, params, axes


def make_plan(rng, vocab):
    n_req = int(rng.integers(2, 5))
    plan = []
    for _ in range(n_req):
        L = int(rng.integers(3, 7))
        plan.append({
            "arrival": int(rng.integers(0, 4)),
            "prompt": rng.integers(0, vocab, size=L).astype(np.int32),
            "max_new": int(rng.integers(1, H_MAX + 1)),
            "stop": tuple(
                int(t) for t in rng.integers(0, vocab, size=2)
            ) if rng.random() < 0.5 else (),
        })
    plan.sort(key=lambda p: p["arrival"])
    # pre-drawn preemption coin flips: both engines see the same eviction
    # schedule as long as their behavior matches (which is the assertion)
    evict_coin = [bool(rng.random() < 0.3) for _ in range(64)]
    return plan, evict_coin


def run_plan(eng, plan, evict_coin):
    pending = list(plan)
    rid_of = {}
    t, n_evicted = 0, 0
    while pending or not eng.scheduler.idle:
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            rid_of[eng.submit(p["prompt"], max_new_tokens=p["max_new"],
                              stop_tokens=p["stop"])] = p
        if (n_evicted < 2 and eng.scheduler.active
                and evict_coin[min(t, len(evict_coin) - 1)]):
            victim = min(r.rid for r in eng.scheduler.active.values())
            if eng.evict(victim):
                n_evicted += 1
        eng.step()
        t += 1
        assert t < 300, "schedule failed to drain"
    return {rid: [int(x) for x in toks]
            for rid, toks in eng.results().items()}, rid_of


def assert_pool_zeroed(eng):
    axes = lm.cache_slot_axes(eng.cfg, eng.pool)
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(
            lambda ax, leaf: jnp.zeros(()) if ax < 0
            else jnp.sum(jnp.abs(leaf.astype(jnp.float32))),
            axes, eng.pool,
        )
    )
    assert all(float(x) == 0.0 for x in leaves), "slot state leaked"


def compare_schedule(arch, seed, n_data=2, n_model=4, expect_sharded=True):
    """One randomized schedule, meshless engine vs mesh engine: token
    streams must be identical and both drained pools exactly zero.

    ``expect_sharded`` additionally asserts the mesh pool is genuinely
    sharded — pick an ``n_model`` the arch's head/channel dims divide."""
    cfg, params, axes = setup(arch)
    rng = np.random.default_rng(seed)
    plan, evict_coin = make_plan(rng, cfg.vocab_size)

    single = ServeEngine(params, cfg, SCFG)
    got_single, _ = run_plan(single, plan, evict_coin)

    mesh = make_debug_mesh(n_data, n_model)
    ectx = ExecutionContext(mesh=mesh)
    meshed = ServeEngine(params, cfg, SCFG, ectx=ectx, param_axes=axes)
    got_mesh, _ = run_plan(meshed, plan, evict_coin)

    assert set(got_single) == set(got_mesh)
    for rid in got_single:
        assert got_single[rid] == got_mesh[rid], (
            f"{arch} seed={seed}: rid {rid} diverged on the mesh: "
            f"{got_mesh[rid]} != {got_single[rid]}"
        )
    assert_pool_zeroed(single)
    assert_pool_zeroed(meshed)
    if expect_sharded:
        # the mesh engine's pool really is sharded (not silently
        # replicated): at least one cache leaf carries a non-trivial spec
        specs = [
            leaf.sharding.spec
            for leaf in jax.tree_util.tree_leaves(meshed.pool)
            if hasattr(leaf.sharding, "spec")
        ]
        assert any(any(e is not None for e in s) for s in specs), specs
    return len(got_single)
