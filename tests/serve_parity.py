"""Shared driver for the mesh-vs-single-device serve parity harness.

Imported by tests/test_serve_distributed.py *inside its 8-device
subprocesses* (PYTHONPATH carries both src/ and tests/): runs one
randomized continuous-batching schedule — arrivals, mixed prompt lengths,
horizons, stop tokens, preemptions — through TWO engines built from the
same params, one meshless and one mesh-native on a 2×4 debug mesh, and
asserts the emitted token streams are identical request-for-request
(DESIGN.md §9: mesh-native serving changes the layout, never the tokens).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import split_params
from repro.configs import get_config
from repro.distributed.execution import ExecutionContext
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine

MAX_LEN = 24
H_MAX = 4
SCFG = ServeConfig(max_len=MAX_LEN, temperature=0.0, n_slots=2,
                   cache_dtype=jnp.float32)


@functools.lru_cache(maxsize=None)
def setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    params, axes = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    return cfg, params, axes


def make_plan(rng, vocab):
    n_req = int(rng.integers(2, 5))
    plan = []
    for _ in range(n_req):
        L = int(rng.integers(3, 7))
        plan.append({
            "arrival": int(rng.integers(0, 4)),
            "prompt": rng.integers(0, vocab, size=L).astype(np.int32),
            "max_new": int(rng.integers(1, H_MAX + 1)),
            "stop": tuple(
                int(t) for t in rng.integers(0, vocab, size=2)
            ) if rng.random() < 0.5 else (),
        })
    plan.sort(key=lambda p: p["arrival"])
    # pre-drawn preemption coin flips: both engines see the same eviction
    # schedule as long as their behavior matches (which is the assertion)
    evict_coin = [bool(rng.random() < 0.3) for _ in range(64)]
    return plan, evict_coin


def run_plan(eng, plan, evict_coin):
    pending = list(plan)
    rid_of = {}
    t, n_evicted = 0, 0
    while pending or not eng.scheduler.idle:
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            rid_of[eng.submit(p["prompt"], max_new_tokens=p["max_new"],
                              stop_tokens=p["stop"])] = p
        if (n_evicted < 2 and eng.scheduler.active
                and evict_coin[min(t, len(evict_coin) - 1)]):
            victim = min(r.rid for r in eng.scheduler.active.values())
            if eng.evict(victim):
                n_evicted += 1
        eng.step()
        t += 1
        assert t < 300, "schedule failed to drain"
    return {rid: [int(x) for x in toks]
            for rid, toks in eng.results().items()}, rid_of


def assert_pool_zeroed(eng):
    axes = lm.cache_slot_axes(eng.cfg, eng.pool)
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(
            lambda ax, leaf: jnp.zeros(()) if ax < 0
            else jnp.sum(jnp.abs(leaf.astype(jnp.float32))),
            axes, eng.pool,
        )
    )
    assert all(float(x) == 0.0 for x in leaves), "slot state leaked"


def compare_schedule(arch, seed, n_data=2, n_model=4, expect_sharded=True):
    """One randomized schedule, meshless engine vs mesh engine: token
    streams must be identical and both drained pools exactly zero.

    ``expect_sharded`` additionally asserts the mesh pool is genuinely
    sharded — pick an ``n_model`` the arch's head/channel dims divide."""
    cfg, params, axes = setup(arch)
    rng = np.random.default_rng(seed)
    plan, evict_coin = make_plan(rng, cfg.vocab_size)

    single = ServeEngine(params, cfg, SCFG)
    got_single, _ = run_plan(single, plan, evict_coin)

    mesh = make_debug_mesh(n_data, n_model)
    ectx = ExecutionContext(mesh=mesh)
    meshed = ServeEngine(params, cfg, SCFG, ectx=ectx, param_axes=axes)
    got_mesh, _ = run_plan(meshed, plan, evict_coin)

    assert set(got_single) == set(got_mesh)
    for rid in got_single:
        assert got_single[rid] == got_mesh[rid], (
            f"{arch} seed={seed}: rid {rid} diverged on the mesh: "
            f"{got_mesh[rid]} != {got_single[rid]}"
        )
    assert_pool_zeroed(single)
    assert_pool_zeroed(meshed)
    if expect_sharded:
        # the mesh engine's pool really is sharded (not silently
        # replicated): at least one cache leaf carries a non-trivial spec
        specs = [
            leaf.sharding.spec
            for leaf in jax.tree_util.tree_leaves(meshed.pool)
            if hasattr(leaf.sharding, "spec")
        ]
        assert any(any(e is not None for e in s) for s in specs), specs
    return len(got_single)


# ----------------------------------------------------------- paged engine
#
# The paged engine absorbs prompts through the decode path (chunked
# prefill), so its logits match the batched-prefill reference to
# *tolerance*, not bit-exactly — different reduction shapes re-associate
# fp sums, and greedy argmax can flip on a near-tie.  The comparison
# therefore replays each request against a reference that carries the
# engine's own (seed, rid, token index) key streams and per-step tie gaps:
# a mismatch is accepted only where the reference's decision margin is
# below TIE_TOL (a genuine near-tie), after which the histories diverge
# and comparison for that request stops.  Mesh-vs-meshless paged runs use
# the same program on both sides and must match exactly.

from repro.serve.engine import request_token_key
from repro.serve.paged import PagedConfig, PagedServeEngine
from repro.serve.sampling import top_k_mask

TIE_TOL = 1e-4
PAGED_MAX_STEPS = 600


@functools.partial(jax.jit, static_argnames=("cfg", "scfg"))
def _ref_prefill(params, prompt, *, cfg, scfg):
    ctx = scfg.apply_context()
    p = ctx.cast_compute(params)
    compute = ctx.compute_dtype or scfg.cache_dtype
    logits, caches = lm.prefill(
        p, cfg, prompt, scfg.max_len, dtype=scfg.cache_dtype,
        compute_dtype=compute, ctx=ctx,
    )
    return logits[:, -1], caches


@functools.partial(jax.jit, static_argnames=("cfg", "scfg"))
def _ref_decode(params, tok, caches, *, cfg, scfg):
    ctx = scfg.apply_context()
    p = ctx.cast_compute(params)
    compute = ctx.compute_dtype or scfg.cache_dtype
    return lm.decode_step(p, cfg, tok, caches, compute_dtype=compute,
                          ctx=ctx)


def sampled_scores(key, logits, temperature, top_k):
    """The decision scores behind ``sample_slots`` for one row: greedy
    rows decide on raw logits, sampled rows on the temperature-scaled,
    top-k-masked, gumbel-perturbed logits (argmax of these IS the sampled
    token — the gumbel trick ``jax.random.categorical`` uses, with the
    same key).  Unit-pinned against sample_slots in test_serve_engine."""
    lg = jnp.asarray(logits, jnp.float32)
    if temperature <= 0.0:
        return lg
    scaled = lg / max(temperature, 1e-6)
    masked = top_k_mask(scaled, jnp.asarray(top_k, jnp.int32))
    return masked + jax.random.gumbel(key, masked.shape, masked.dtype)


def paged_reference(cfg, params, scfg, prompt, sp, rid, seed=0):
    """Expected stream for one request under the engine's key streams,
    plus the per-step decision margin (top-2 score gap) used to classify
    mismatches as near-ties."""
    base_key = jax.random.PRNGKey(seed)
    lg, caches = _ref_prefill(
        params, jnp.asarray(prompt[None]), cfg=cfg, scfg=scfg,
    )
    lg = lg[0]
    toks, gaps = [], []
    for k in range(sp.max_new_tokens):
        key = request_token_key(base_key, jnp.asarray(rid, jnp.int32),
                                jnp.asarray(k, jnp.int32))
        scores = sampled_scores(key, lg, sp.temperature, sp.top_k)
        top2 = jax.lax.top_k(scores, 2)[0]
        tok = int(jnp.argmax(scores))
        toks.append(tok)
        gaps.append(float(top2[0] - top2[1]))
        if tok in sp.stop_tokens:
            break
        lg, caches = _ref_decode(
            params, jnp.asarray([tok], jnp.int32), caches,
            cfg=cfg, scfg=scfg,
        )
        lg = lg[0]
    return toks, gaps


def compare_request(got, want, gaps, label):
    """Token-identical up to the first reference near-tie (margin below
    TIE_TOL), after which histories legitimately diverge."""
    for i, g in enumerate(got):
        assert i < len(want), (
            f"{label}: emitted {len(got)} tokens past the reference's "
            f"stop at {len(want)} without a near-tie divergence: {got}"
        )
        if g != want[i]:
            assert gaps[i] < TIE_TOL, (
                f"{label}: token {i} diverged ({g} != {want[i]}) with a "
                f"decision margin of {gaps[i]:.3e} — a real mismatch, not "
                f"a near-tie.  got={got} want={want}"
            )
            return
    assert len(got) == len(want), (
        f"{label}: stream truncated without divergence: {got} vs {want}"
    )


def make_paged_plan(rng, vocab):
    """Randomized paged-serving scenario: a shared system prefix (~half
    the requests fork it), mixed greedy/sampled requests, priorities,
    random page size / quantum, and optional block-pool pressure."""
    page = int(rng.choice([2, 4]))
    quantum = int(rng.integers(1, 4))
    shared = rng.integers(0, vocab, size=int(rng.integers(4, 11))).astype(
        np.int32
    )
    n_req = int(rng.integers(3, 6))
    reqs = []
    for _ in range(n_req):
        if rng.random() < 0.5:
            tail = rng.integers(0, vocab, size=int(rng.integers(1, 5)))
            prompt = np.concatenate([shared, tail]).astype(np.int32)
        else:
            prompt = rng.integers(
                0, vocab, size=int(rng.integers(3, 9))
            ).astype(np.int32)
        sampled = rng.random() < 0.3
        reqs.append({
            "arrival": int(rng.integers(0, 7)),
            "prompt": prompt,
            "max_new": int(rng.integers(1, H_MAX + 1)),
            "stop": tuple(
                int(t) for t in rng.integers(0, vocab, size=2)
            ) if rng.random() < 0.4 else (),
            "temperature": 0.7 if sampled else 0.0,
            "top_k": 4 if (sampled and rng.random() < 0.5) else 0,
            "priority": int(rng.integers(0, 3)),
        })
    reqs.sort(key=lambda p: p["arrival"])
    max_need = max(
        -(-(len(p["prompt"]) + p["max_new"]) // page) for p in reqs
    )
    n_blocks = 0  # auto (no pressure)
    if rng.random() < 0.5:  # tight pool: forces preemption cascades
        n_blocks = max_need + int(rng.integers(0, max_need + 1)) + 1
    pcfg = PagedConfig(page_size=page, n_blocks=n_blocks,
                       prefix_cache=bool(rng.random() < 0.8))
    scfg = dataclasses.replace(SCFG, decode_quantum=quantum)
    coins = {
        "evict": [bool(rng.random() < 0.25) for _ in range(64)],
        "radix": [bool(rng.random() < 0.25) for _ in range(64)],
    }
    return reqs, scfg, pcfg, coins


def run_paged_plan(eng, reqs, coins, chaos_rng):
    """Drive arrivals + chaos (resident eviction, random radix-node drops)
    until drained; returns rid -> tokens and rid -> plan entry."""
    pending = list(reqs)
    rid_of = {}
    t, n_evicted = 0, 0
    while pending or not eng.idle:
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            rid_of[eng.submit(
                p["prompt"], max_new_tokens=p["max_new"],
                stop_tokens=p["stop"], temperature=p["temperature"],
                top_k=p["top_k"], priority=p["priority"],
            )] = p
        coin = coins["evict"][min(t, 63)]
        if n_evicted < 2 and eng.residents and coin:
            victim = min(r.rid for r in eng.residents.values())
            if eng.evict(victim):
                n_evicted += 1
        if coins["radix"][min(t, 63)]:
            eng.evict_prefix_node(chaos_rng)
        eng.step()
        t += 1
        assert t < PAGED_MAX_STEPS, "paged schedule failed to drain"
    return {rid: [int(x) for x in toks]
            for rid, toks in eng.results().items()}, rid_of


def check_paged_schedule(arch, seed, *, ectx=None, param_axes=None):
    """One randomized paged schedule vs the per-request reference (tie-
    aware), then the clean-pool invariants.  Returns (results, plan map,
    scfg) so callers can run additional comparisons."""
    cfg, params, axes = setup(arch)
    rng = np.random.default_rng(seed)
    reqs, scfg, pcfg, coins = make_paged_plan(rng, cfg.vocab_size)
    eng = PagedServeEngine(
        params, cfg, scfg, pcfg, ectx=ectx,
        param_axes=param_axes if ectx is not None else None,
    )
    got, rid_of = run_paged_plan(eng, reqs, coins,
                                 np.random.default_rng(seed + 1))
    from repro.serve.scheduler import SamplingParams

    for rid, p in rid_of.items():
        sp = SamplingParams(
            max_new_tokens=p["max_new"], temperature=p["temperature"],
            top_k=p["top_k"], stop_tokens=p["stop"],
        )
        want, gaps = paged_reference(cfg, params, scfg, p["prompt"], sp, rid)
        compare_request(
            got[rid], want, gaps,
            f"{arch} seed={seed} rid={rid} "
            f"(page={pcfg.page_size} q={scfg.decode_quantum} "
            f"blocks={pcfg.n_blocks} prefix={pcfg.prefix_cache})",
        )
    eng.flush_prefix()
    eng.check_clean()
    return got, rid_of, scfg


# ------------------------------------------------------------ chaos harness
#
# The serve fault contract (DESIGN.md §13), proved under *injected* faults:
# with deterministic, seeded NaN/Inf logit poisoning, transient step and
# prefill errors, allocator exhaustion, deadlines, cancellations, and load
# shedding all active at once, every submitted request must reach exactly
# one structured terminal RequestResult; completed requests must be token-
# identical to the fault-free sequential reference (exact on the dense
# engine, tie-aware on the paged engine's chunked prefill); "failed" may
# only arise from quarantine strike-out; and the drained engine's pools
# must be fully free (no leaked slots, blocks, refcounts, or radix pins).

from repro.serve.engine import ServeEngine as _ServeEngine  # noqa: E402
from repro.serve.faults import (  # noqa: E402
    FaultInjector,
    FaultPlan,
    TransientStepError,
)
from repro.serve.scheduler import SamplingParams, TERMINAL_STATUSES  # noqa: E402

CHAOS_MAX_STEPS = 800


def make_chaos_plan(rng, vocab):
    """Randomized adversarial scenario: mixed requests (greedy/sampled,
    priorities, some with tick deadlines, some scheduled for mid-flight
    cancellation) plus a seeded FaultPlan drawing every injectable fault
    kind at random rates."""
    n_req = int(rng.integers(3, 6))
    reqs = []
    for _ in range(n_req):
        sampled = rng.random() < 0.3
        reqs.append({
            "arrival": int(rng.integers(0, 6)),
            "prompt": rng.integers(
                0, vocab, size=int(rng.integers(3, 9))
            ).astype(np.int32),
            "max_new": int(rng.integers(1, H_MAX + 1)),
            "stop": tuple(
                int(t) for t in rng.integers(0, vocab, size=2)
            ) if rng.random() < 0.3 else (),
            "temperature": 0.7 if sampled else 0.0,
            "top_k": 4 if (sampled and rng.random() < 0.5) else 0,
            "priority": int(rng.integers(0, 3)),
            # relative to the submission tick; None = no deadline
            "deadline_rel": int(rng.integers(2, 10))
            if rng.random() < 0.25 else None,
            "cancel_after": int(rng.integers(1, 5))
            if rng.random() < 0.25 else None,
        })
    reqs.sort(key=lambda p: p["arrival"])
    fault = dict(
        seed=int(rng.integers(0, 1 << 31)),
        nan_logit_rate=float(rng.choice([0.0, 0.05, 0.15])),
        inf_logit_rate=float(rng.choice([0.0, 0.05])),
        step_error_rate=float(rng.choice([0.0, 0.1, 0.25])),
        prefill_error_rate=float(rng.choice([0.0, 0.1])),
        alloc_fail_rate=float(rng.choice([0.0, 0.2])),
    )
    scfg = dataclasses.replace(
        SCFG,
        decode_quantum=int(rng.integers(1, 4)),
        overload_threshold=int(rng.choice([0, 0, 3])),
        step_retry_attempts=3,
    )
    pcfg = PagedConfig(page_size=int(rng.choice([2, 4])),
                       prefix_cache=bool(rng.random() < 0.8))
    return reqs, fault, scfg, pcfg


def run_chaos_plan(eng, reqs, max_steps=CHAOS_MAX_STEPS):
    """Drive arrivals + scheduled cancellations until drained, absorbing
    retry-exhausted TransientStepErrors (the engine is left consistent, so
    the next step resumes).  Returns rid -> plan entry."""
    paged = hasattr(eng, "alloc")
    pending = list(reqs)
    rid_of, cancels = {}, []
    t = 0

    def idle():
        return eng.idle if paged else eng.scheduler.idle

    while pending or not idle():
        while pending and pending[0]["arrival"] <= t:
            p = pending.pop(0)
            kw = dict(
                max_new_tokens=p["max_new"], stop_tokens=p["stop"],
                temperature=p["temperature"], top_k=p["top_k"],
            )
            if p["deadline_rel"] is not None:
                kw["deadline"] = eng._tick + p["deadline_rel"]
            if paged:
                kw["priority"] = p["priority"]
            rid = eng.submit(p["prompt"], **kw)
            rid_of[rid] = p
            if p["cancel_after"] is not None:
                cancels.append([t + p["cancel_after"], rid])
        for c in [c for c in cancels if c[0] <= t]:
            eng.cancel(c[1])
            cancels.remove(c)
        try:
            eng.step()
        except TransientStepError:
            pass  # bounded retries exhausted this tick; state consistent
        t += 1
        assert t < max_steps, "chaos schedule failed to drain"
    return rid_of


def _check_chaos_results(eng, rid_of, cfg, params, scfg, paged, label):
    finals = eng.request_results()
    for rid, p in rid_of.items():
        assert rid in finals, f"{label}: rid {rid} has no terminal result"
        res = finals[rid]
        assert res.status in TERMINAL_STATUSES, res
        sp = SamplingParams(
            max_new_tokens=p["max_new"], temperature=p["temperature"],
            top_k=p["top_k"], stop_tokens=p["stop"],
        )
        want, gaps = paged_reference(cfg, params, scfg, p["prompt"], sp, rid)
        got = [int(x) for x in res.tokens]
        rlabel = f"{label} rid={rid} status={res.status}"
        if res.status == "completed":
            # token-identical to the fault-free sequential reference —
            # injected faults on THIS request were healed by retry /
            # quarantine-replay, and faults on batch neighbors never
            # leak across slots
            if paged:
                compare_request(got, want, gaps, rlabel)
            else:
                assert got == want, f"{rlabel}: {got} != {want}"
        else:
            # structured terminations carry a partial prefix of the
            # reference stream (exact on dense; prefix-compared
            # tie-aware on paged)
            if res.status == "failed":
                assert eng.n_quarantined > 0, (
                    f"{rlabel}: failed without any quarantine"
                )
            if paged:
                for i, g in enumerate(got):
                    if g != want[i]:
                        assert gaps[i] < TIE_TOL, (
                            f"{rlabel}: partial token {i} diverged"
                        )
                        break
            else:
                assert got == want[: len(got)], (
                    f"{rlabel}: partial tokens {got} not a prefix of "
                    f"{want}"
                )
    return finals


def check_chaos_schedule(arch, seed, *, paged=False, ectx=None,
                         param_axes=None):
    """One randomized chaos schedule on a freshly built engine; asserts
    the full serve fault contract, then the clean-pool invariants.
    Returns (injector.fired counts, rid -> RequestResult)."""
    cfg, params, axes = setup(arch)
    rng = np.random.default_rng(seed)
    reqs, fault, scfg, pcfg = make_chaos_plan(rng, cfg.vocab_size)
    inj = FaultInjector(FaultPlan(**fault))
    if paged:
        eng = PagedServeEngine(
            params, cfg, scfg, pcfg, injector=inj, ectx=ectx,
            param_axes=param_axes if ectx is not None else None,
        )
    else:
        eng = _ServeEngine(
            params, cfg, scfg, injector=inj, ectx=ectx,
            param_axes=param_axes if ectx is not None else None,
        )
    rid_of = run_chaos_plan(eng, reqs)
    label = (f"{arch} seed={seed} paged={paged} q={scfg.decode_quantum} "
             f"fault={fault}")
    finals = _check_chaos_results(eng, rid_of, cfg, params, scfg, paged,
                                  label)
    # post-drain: no leaked slots / blocks / refcounts / radix pins
    if paged:
        eng.flush_prefix()
        eng.check_clean()
    else:
        assert eng.scheduler.idle
        assert len(eng.scheduler._free) == scfg.n_slots, "leaked slots"
        assert_pool_zeroed(eng)
    return inj.fired, finals


def compare_chaos_mesh(arch, seed, n_data=2, n_model=4):
    """The same chaos schedule meshless vs mesh-native (dense engine):
    identical injected fault streams on both sides, so every request's
    terminal status AND tokens must match exactly."""
    cfg, params, axes = setup(arch)
    rng = np.random.default_rng(seed)
    reqs, fault, scfg, _ = make_chaos_plan(rng, cfg.vocab_size)

    single = _ServeEngine(params, cfg, scfg,
                          injector=FaultInjector(FaultPlan(**fault)))
    rid_single = run_chaos_plan(single, reqs)
    got_single = single.request_results()

    mesh = make_debug_mesh(n_data, n_model)
    ectx = ExecutionContext(mesh=mesh)
    meshed = _ServeEngine(params, cfg, scfg, ectx=ectx, param_axes=axes,
                          injector=FaultInjector(FaultPlan(**fault)))
    rid_mesh = run_chaos_plan(meshed, reqs)
    got_mesh = meshed.request_results()

    assert set(rid_single) == set(rid_mesh)
    assert set(got_single) == set(got_mesh)
    for rid in got_single:
        a, b = got_single[rid], got_mesh[rid]
        assert (a.status, a.tokens) == (b.status, b.tokens), (
            f"{arch} seed={seed}: rid {rid} diverged on the mesh under "
            f"chaos: {(b.status, b.tokens)} != {(a.status, a.tokens)}"
        )
    for eng in (single, meshed):
        assert eng.scheduler.idle
        assert_pool_zeroed(eng)
    return len(got_single)


def compare_paged_mesh(arch, seed, n_data=2, n_model=4,
                       expect_sharded=True):
    """The same randomized paged schedule on a debug mesh vs meshless:
    identical programs, so token streams must match exactly; the physical
    block pool must be genuinely sharded."""
    cfg, params, axes = setup(arch)
    rng = np.random.default_rng(seed)
    reqs, scfg, pcfg, coins = make_paged_plan(rng, cfg.vocab_size)

    single = PagedServeEngine(params, cfg, scfg, pcfg)
    got_single, _ = run_paged_plan(single, reqs, coins,
                                   np.random.default_rng(seed + 1))

    mesh = make_debug_mesh(n_data, n_model)
    ectx = ExecutionContext(mesh=mesh)
    meshed = PagedServeEngine(params, cfg, scfg, pcfg, ectx=ectx,
                              param_axes=axes)
    got_mesh, _ = run_paged_plan(meshed, reqs, coins,
                                 np.random.default_rng(seed + 1))

    assert set(got_single) == set(got_mesh)
    for rid in got_single:
        assert got_single[rid] == got_mesh[rid], (
            f"{arch} seed={seed}: paged rid {rid} diverged on the mesh: "
            f"{got_mesh[rid]} != {got_single[rid]}"
        )
    for eng in (single, meshed):
        eng.flush_prefix()
        eng.check_clean()
    if expect_sharded:
        specs = [
            leaf.sharding.spec
            for leaf in meshed._phys + meshed._pinned + meshed._shared
            if hasattr(leaf.sharding, "spec")
        ]
        assert any(any(e is not None for e in s) for s in specs), specs
    return len(got_single)
