"""Serving consistency: prefill+decode reproduces teacher-forced forward
for every mixer family; ring buffers, sampling, generate loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.param import split_params
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, generate, serve_step
from repro.serve.sampling import sample

# one arch per mixer family (reduced): GQA, local-attn hybrid, hyena, ssd, moe
CONSISTENCY_ARCHS = [
    "phi4-mini-3.8b",      # GQA attention
    "recurrentgemma-2b",   # rglru + local attention (+ tail layers)
    "hyena-153m",          # hyena
    "mamba2-130m",         # ssd
    "granite-moe-3b-a800m",  # attention + MoE channel mixer
]


def setup(arch, L=12, B=2, seed=0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, frontend_len=0, frontend=None)
    if cfg.moe:
        # lift capacity so no tokens drop: teacher-forced routing drops
        # under per-batch capacity while single-token decode does not —
        # correct MoE semantics, but not what this consistency test probes.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(seed), cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, L), 0,
                                cfg.vocab_size)
    return cfg, params, tokens


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_plus_decode_matches_forward(arch):
    """Teacher-forced: forward(tokens[0:L]) last logits == prefill(0:L-1)
    then decode(token L-1)."""
    L = 12
    cfg, params, tokens = setup(arch, L=L)
    # full forward at max_len grid (hyena filters are grid-dependent, so the
    # reference is computed through prefill at the same max_len)
    ref_logits, _ = lm.prefill(params, cfg, tokens, max_len=L, dtype=jnp.float32)
    _, caches = lm.prefill(params, cfg, tokens[:, : L - 1], max_len=L,
                           dtype=jnp.float32)
    step_logits, _ = lm.decode_step(params, cfg, tokens[:, L - 1], caches,
                                    compute_dtype=jnp.float32)
    # fp32 compute: cache algebra must be near-exact (bf16 noise would flip
    # MoE top-k routing; dtype robustness is covered by the bf16 test below)
    tol = 1e-3
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(ref_logits[:, -1]),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("arch", ["hyena-153m", "mamba2-130m"])
def test_multi_step_decode_consistency(arch):
    """Decode 4 tokens one-by-one == teacher-forced logits at each step."""
    L, T = 8, 4
    cfg, params, tokens = setup(arch, L=L + T)
    ref_logits, _ = lm.prefill(params, cfg, tokens, max_len=L + T,
                               dtype=jnp.float32)
    _, caches = lm.prefill(params, cfg, tokens[:, :L], max_len=L + T,
                           dtype=jnp.float32)
    for t in range(T):
        lg, caches = lm.decode_step(params, cfg, tokens[:, L + t], caches,
                                    compute_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(ref_logits[:, L + t]),
            rtol=1e-3, atol=1e-3,
        )


def test_sliding_window_ring_buffer():
    """Local attention ring buffer gives the same result as recomputing
    windowed attention over the full history."""
    cfg, params, tokens = setup("recurrentgemma-2b", L=40)
    assert cfg.local_window > 0 and cfg.local_window < 40
    ref_logits, _ = lm.prefill(params, cfg, tokens, max_len=40, dtype=jnp.float32)
    _, caches = lm.prefill(params, cfg, tokens[:, :39], max_len=40,
                           dtype=jnp.float32)
    lg, _ = lm.decode_step(params, cfg, tokens[:, 39], caches,
                           compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_logits[:, -1]),
                               rtol=1e-3, atol=1e-3)


def test_generate_greedy_deterministic():
    cfg, params, tokens = setup("hyena-153m", L=8)
    scfg = ServeConfig(max_len=32, temperature=0.0)
    out1 = generate(params, cfg, tokens, scfg=scfg, max_new_tokens=5)
    out2 = generate(params, cfg, tokens, scfg=scfg, max_new_tokens=5)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_sampling_modes():
    logits = jnp.asarray([[0.0, 10.0, 0.0], [5.0, 0.0, 0.0]])
    assert list(np.asarray(sample(jax.random.PRNGKey(0), logits))) == [1, 0]
    s = sample(jax.random.PRNGKey(0), logits, temperature=1.0, top_k=1)
    assert list(np.asarray(s)) == [1, 0]


def test_serve_step_signature():
    cfg, params, tokens = setup("phi4-mini-3.8b", L=4)
    caches = lm.init_caches(cfg, 2, max_len=8, dtype=jnp.float32)
    lg, caches = serve_step(params, cfg, tokens[:, 0], caches)
    assert lg.shape == (2, cfg.vocab_size)


def test_bf16_decode_close_to_fp32():
    """Default bf16 serving stays within a few ulp of the fp32 path."""
    cfg, params, tokens = setup("hyena-153m", L=10)
    ref, _ = lm.prefill(params, cfg, tokens, max_len=10, dtype=jnp.float32)
    _, caches = lm.prefill(params, cfg, tokens[:, :9], max_len=10,
                           dtype=jnp.bfloat16)
    lg, _ = lm.decode_step(params, cfg, tokens[:, 9], caches)  # bf16 compute
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, -1]),
                               rtol=8e-2, atol=8e-2)
