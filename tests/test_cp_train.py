"""Context-parallel training tests (DESIGN.md §12).

Multi-device cases run in subprocesses with
``--xla_force_host_platform_device_count=8`` (same idiom as
test_distributed.py) so the main process keeps seeing one device.  The
tolerance story: everything runs the FP32 policy, so cp-vs-single-device
parity is pinned near machine epsilon — loss to 1e-4, grads to 1e-3
relative — not the loose envelopes the bf16 mesh tests need.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


# ------------------------------------------------ conv VJP grad parity

def test_sp_conv_grad_parity_all_shapes():
    """sp_fft_causal_conv custom_vjp vs fft_causal_conv under jax.grad:
    du/dh for divisible L=64 and padded L=60, with and without gate/skip,
    plus dskip/dgate — the backward's transposed distributed FFT must
    match the local reference to fp32 noise."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.spconv import sp_fft_causal_conv
        from repro.core.fftconv import fft_causal_conv

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        B, L, D = 4, 64, 8
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        u = jax.random.normal(k1, (B, L, D), jnp.float32)
        h = jax.random.normal(k2, (D, L), jnp.float32) * 0.1
        skip = jax.random.normal(k3, (D,), jnp.float32)
        gate = jax.random.normal(k4, (B, L, D), jnp.float32)
        dy = jax.random.normal(k5, (B, L, D), jnp.float32)

        def check(name, a, b, tol=2e-3):
            d = float(jnp.max(jnp.abs(a - b)))
            s = float(jnp.max(jnp.abs(b))) + 1e-8
            assert d / s < tol, f"{name}: rel={d/s:.2e}"

        for Lt in (64, 60):  # 60 exercises the pad-to-divisible path
            ut, ht, gt, dyt = u[:, :Lt], h[:, :Lt], gate[:, :Lt], dy[:, :Lt]
            for g in (None, gt):
                for sk in (None, skip):
                    lbl = f"L={Lt} gate={g is not None} skip={sk is not None}"
                    ref_f = lambda uu, hh: fft_causal_conv(uu, hh, sk, g)
                    sp_f = lambda uu, hh: sp_fft_causal_conv(
                        uu, hh, sk, mesh, axis="model", gate=g)
                    check(f"fwd {lbl}", jax.jit(sp_f)(ut, ht), ref_f(ut, ht))
                    lr = lambda uu, hh: jnp.sum(ref_f(uu, hh) * dyt)
                    ls = lambda uu, hh: jnp.sum(sp_f(uu, hh) * dyt)
                    gr = jax.grad(lr, argnums=(0, 1))(ut, ht)
                    gs = jax.jit(jax.grad(ls, argnums=(0, 1)))(ut, ht)
                    check(f"du {lbl}", gs[0], gr[0])
                    check(f"dh {lbl}", gs[1], gr[1])
        ls = lambda sk, g: jnp.sum(
            sp_fft_causal_conv(u, h, sk, mesh, axis="model", gate=g) * dy)
        lr = lambda sk, g: jnp.sum(fft_causal_conv(u, h, sk, g) * dy)
        gs = jax.jit(jax.grad(ls, argnums=(0, 1)))(skip, gate)
        gr = jax.grad(lr, argnums=(0, 1))(skip, gate)
        check("dskip", gs[0], gr[0])
        check("dgate", gs[1], gr[1])
        print("CONV-VJP-OK")
    """)
    assert "CONV-VJP-OK" in out


def test_mesh_conv_backends_grad_parity_vs_local():
    """Every mesh-aware registry backend (fft, fft_sp) must agree with
    fft_local under jax.grad — including the gate-fused epilogue (satellite:
    gate fusion must be bit-compatible in the backward too)."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import conv_api
        from repro.distributed.ctx import use_mesh

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        key = jax.random.PRNGKey(3)
        B, L, D = 4, 60, 8   # non-divisible L: fft_sp pads internally
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        u = jax.random.normal(k1, (B, L, D), jnp.float32)
        h = jax.random.normal(k2, (D, L), jnp.float32) * 0.1
        skip = jax.random.normal(k3, (D,), jnp.float32)
        gate = jax.random.normal(k4, (B, L, D), jnp.float32)
        dy = jax.random.normal(k5, (B, L, D), jnp.float32)

        def grads(backend, g):
            conv = conv_api.get_conv_backend(backend)
            f = lambda uu, hh, sk: jnp.sum(conv(uu, hh, sk, gate=g) * dy)
            with use_mesh(mesh):
                return jax.jit(jax.grad(f, argnums=(0, 1, 2)))(u, h, skip)

        for g in (None, gate):
            ref = grads("fft_local", g)
            for backend in ("fft", "fft_sp"):
                got = grads(backend, g)
                for r, o, nm in zip(ref, got, ("du", "dh", "dskip")):
                    d = float(jnp.max(jnp.abs(r - o)))
                    s = float(jnp.max(jnp.abs(r))) + 1e-8
                    assert d / s < 2e-3, (
                        f"{backend} {nm} gate={g is not None}: {d/s:.2e}")
        print("BACKENDS-OK")
    """)
    assert "BACKENDS-OK" in out


# ------------------------------------------------ ring / allgather attn

def test_cp_attention_grad_parity():
    """Ring and masked-allgather cp attention vs chunked_attention —
    forward and dq/dk/dv, full-causal and windowed (GQA shapes)."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.models.attention import (
            cp_ring_attention, cp_allgather_attention, chunked_attention)

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        key = jax.random.PRNGKey(1)
        B, L, H, Hkv, Dh = 4, 64, 4, 2, 16
        kq, kk, kv, kd = jax.random.split(key, 4)
        q = jax.random.normal(kq, (B, L, H, Dh), jnp.float32)
        k = jax.random.normal(kk, (B, L, Hkv, Dh), jnp.float32)
        v = jax.random.normal(kv, (B, L, Hkv, Dh), jnp.float32)
        dy = jax.random.normal(kd, (B, L, H, Dh), jnp.float32)

        def check(name, a, b, tol=2e-3):
            d = float(jnp.max(jnp.abs(a - b)))
            s = float(jnp.max(jnp.abs(b))) + 1e-8
            assert d / s < tol, f"{name}: rel={d/s:.2e}"

        for window in (None, 24):
            ref = chunked_attention(q, k, v, causal=True, window=window,
                                    q_offset=0, chunk_kv=16)
            for name, fn in (("ring", cp_ring_attention),
                             ("allgather", cp_allgather_attention)):
                f = lambda q_, k_, v_: fn(q_, k_, v_, mesh=mesh,
                                          axis="model", window=window,
                                          q_offset=0)
                check(f"{name} fwd w={window}", jax.jit(f)(q, k, v), ref)
                lr = lambda q_, k_, v_: jnp.sum(chunked_attention(
                    q_, k_, v_, causal=True, window=window, chunk_kv=16) * dy)
                ls = lambda q_, k_, v_: jnp.sum(f(q_, k_, v_) * dy)
                gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
                gs = jax.jit(jax.grad(ls, argnums=(0, 1, 2)))(q, k, v)
                for i, nm in enumerate("qkv"):
                    check(f"{name} d{nm} w={window}", gs[i], gr[i])
        print("ATTN-OK")
    """)
    assert "ATTN-OK" in out


# ------------------------------------------- per-mixer train-step parity

def test_cp_train_step_matches_single_device_per_mixer():
    """The acceptance gate: for every registered training mixer, loss AND
    grads of the cp-sharded step (2x4 mesh, cp over 'model') match the
    single-device step under the FP32 policy.  hyena runs with remat=True
    to prove cp composes with rematerialization."""
    out = run_subprocess("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        def small_cfg(mixer):
            return ModelConfig(
                name=f"cp-{mixer}", family="test",
                n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                d_ff=64, vocab_size=64, pattern=(mixer,), local_window=8,
                ssm_state=16, ssd_head_dim=16, rnn_width=32,
                hyena_filter_width=16, hyena_pos_dim=9,
            )

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, L = 8, 32
        for mixer in ["hyena", "attention", "local_attention", "ssd"]:
            cfg = small_cfg(mixer)
            tok = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, 64)
            lab = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, 64)
            batch = {"tokens": tok, "labels": lab}
            tcfg1 = T.TrainConfig(
                optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
                remat=(mixer == "hyena"), policy=FP32)
            tcfg2 = dataclasses.replace(tcfg1, cp_axis="model")
            state, axes = T.init_train_state(jax.random.PRNGKey(0), cfg)
            params = state["params"]

            ctx1 = tcfg1.apply_context()
            (l1, _), g1 = jax.value_and_grad(
                lambda p, b: T._loss(p, cfg, tcfg1, ctx1, b),
                has_aux=True)(params, batch)

            ectx = tcfg2.apply_context(mesh=mesh)
            p2 = jax.device_put(params, ectx.param_shardings(axes, params))
            b2 = {k: jax.device_put(
                      v, ectx.data_sharding(v.ndim, v.shape[0], v.shape[1]))
                  for k, v in batch.items()}
            ctx2 = tcfg2.apply_context()
            with ectx.scope():
                (l2, _), g2 = jax.jit(jax.value_and_grad(
                    lambda p, b: T._loss(p, cfg, tcfg2, ctx2, b),
                    has_aux=True))(p2, b2)
                l2 = float(l2)
            dl = abs(float(l1) - l2)
            worst = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(g1),
                            jax.tree_util.tree_leaves(g2)):
                a = np.asarray(a, np.float32)
                b = np.asarray(jax.device_get(b), np.float32)
                scale = max(np.abs(a).max(), 1e-6)
                worst = max(worst, np.abs(a - b).max() / scale)
            assert dl < 1e-4, f"{mixer}: dloss={dl:.2e}"
            assert worst < 1e-3, f"{mixer}: grad_rel={worst:.2e}"
            print(f"{mixer} dloss={dl:.2e} grad_rel={worst:.2e} OK")
        print("MIXERS-OK")
    """)
    assert "MIXERS-OK" in out


def test_cp_train_step_multihybrid_se_mr_li_attn():
    """ISSUE 9 acceptance row: the 4-way SE-MR-LI-attn multi-hybrid
    pattern (DESIGN.md §14) trains under cp_axis with loss AND grads
    matching the single-device step — SE's fp32 FIR, MR's fixed-support
    taps through the cp conv backend, LI's fft_sp VJP, and ring attention
    all in ONE network.  remat=True like the per-mixer hyena row: the
    checkpoint boundary keeps the partitioner honoring the filter FFN's
    seq-sharding constraints."""
    out = run_subprocess("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        cfg = ModelConfig(
            name="cp-mh", family="test",
            n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab_size=64,
            pattern=("hyena_se", "hyena_mr", "hyena_li", "attention"),
            local_window=8, ssm_state=16, ssd_head_dim=16, rnn_width=32,
            hyena_filter_width=16, hyena_pos_dim=9,
            hyena_se_len=4, hyena_mr_support=8,
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, L = 8, 32
        tok = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, 64)
        lab = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, 64)
        batch = {"tokens": tok, "labels": lab}
        tcfg1 = T.TrainConfig(
            optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
            remat=True, policy=FP32)
        tcfg2 = dataclasses.replace(tcfg1, cp_axis="model")
        state, axes = T.init_train_state(jax.random.PRNGKey(0), cfg)
        params = state["params"]

        ctx1 = tcfg1.apply_context()
        (l1, _), g1 = jax.value_and_grad(
            lambda p, b: T._loss(p, cfg, tcfg1, ctx1, b),
            has_aux=True)(params, batch)

        ectx = tcfg2.apply_context(mesh=mesh)
        p2 = jax.device_put(params, ectx.param_shardings(axes, params))
        b2 = {k: jax.device_put(
                  v, ectx.data_sharding(v.ndim, v.shape[0], v.shape[1]))
              for k, v in batch.items()}
        ctx2 = tcfg2.apply_context()
        with ectx.scope():
            (l2, _), g2 = jax.jit(jax.value_and_grad(
                lambda p, b: T._loss(p, cfg, tcfg2, ctx2, b),
                has_aux=True))(p2, b2)
            l2 = float(l2)
        dl = abs(float(l1) - l2)
        worst = 0.0
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g2)):
            a = np.asarray(a, np.float32)
            b = np.asarray(jax.device_get(b), np.float32)
            scale = max(np.abs(a).max(), 1e-6)
            worst = max(worst, np.abs(a - b).max() / scale)
        assert dl < 1e-4, f"dloss={dl:.2e}"
        assert worst < 1e-3, f"grad_rel={worst:.2e}"
        print(f"MH-OK dloss={dl:.2e} grad_rel={worst:.2e}")
    """)
    assert "MH-OK" in out


def test_cp_full_train_step_runs_and_composes():
    """End-to-end make_train_step under cp: optimizer update, microbatches,
    and in-step halo-exchanged targets (no labels in the batch), finite
    loss, params actually move."""
    out = run_subprocess("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        cfg = ModelConfig(
            name="cp-e2e", family="test",
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab_size=64, pattern=("hyena", "attention"),
            local_window=8, ssm_state=16, ssd_head_dim=16, rnn_width=32,
            hyena_filter_width=16, hyena_pos_dim=9,
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        tcfg = T.TrainConfig(
            optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0),
            remat=True, policy=FP32, cp_axis="model", microbatches=2)
        ectx = tcfg.apply_context(mesh=mesh)
        state, axes = T.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        state = ectx.place(state, ectx.train_state_shardings(axes, state))
        tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)
        batch = {"tokens": jax.device_put(
            tok, ectx.data_sharding(2, 8, 32))}
        step = T.jit_train_step(cfg, tcfg)
        with ectx.scope():
            p0 = jax.device_get(
                jax.tree_util.tree_leaves(state["params"])[0])
            state, m = step(state, batch)
            state, m = step(state, batch)
            loss = float(m["loss"])
            p1 = jax.device_get(
                jax.tree_util.tree_leaves(state["params"])[0])
        assert np.isfinite(loss), loss
        assert np.abs(p1 - p0).max() > 0, "params did not move"
        print(f"E2E-OK loss={loss:.3f}")
    """)
    assert "E2E-OK" in out


# --------------------------------------------------- halo target shift

def test_cp_shift_targets_matches_plain_shift():
    """One-token halo exchange across shard boundaries reproduces the
    plain shifted-by-one targets exactly; the last global position is
    IGNORE-masked."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.trainer import cp_shift_targets

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        tok = jax.random.randint(jax.random.PRNGKey(0), (4, 32), 0, 64)
        ref = cp_shift_targets(tok)  # plain concat shift
        got = jax.jit(lambda t: cp_shift_targets(t, mesh, "model"))(tok)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        assert int(ref[0, -1]) == -1
        print("HALO-OK")
    """)
    assert "HALO-OK" in out


# ------------------------------------------- in-process (single device)

def test_microbatch_validation_names_batch_and_axis():
    """make_train_step(microbatches=n) with B % n != 0 must raise an
    actionable ValueError naming B, n, and the data axis — not a raw
    reshape trace error."""
    from repro.configs.base import ModelConfig
    from repro.train import optim as O
    from repro.train import trainer as T

    cfg = ModelConfig(
        name="mb-val", family="test",
        n_layers=1, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, pattern=("hyena",), local_window=8,
        ssm_state=16, ssd_head_dim=16, rnn_width=32,
        hyena_filter_width=16, hyena_pos_dim=9,
    )
    tcfg = T.TrainConfig(
        optimizer=O.AdamWConfig(lr=1e-3, warmup_steps=0), microbatches=2
    )
    state, _ = T.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    tok = jnp.zeros((3, 16), jnp.int32)  # B=3 not divisible by n=2
    step = T.make_train_step(cfg, tcfg)
    with pytest.raises(ValueError) as ei:
        step(state, {"tokens": tok})
    msg = str(ei.value)
    assert "microbatches=2" in msg
    assert "B=3" in msg
    assert "data" in msg


def test_fft_sp_off_mesh_fallback_warns_once():
    """Satellite bugfix: fft_sp off-mesh silently fell back to the local
    full-L FFT.  It must still fall back (correctness) but warn exactly
    once, and the result must match the local reference."""
    import warnings

    from repro.core import conv_api
    from repro.core.fftconv import fft_causal_conv

    u = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4), jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(1), (4, 16), jnp.float32)
    conv = conv_api.get_conv_backend("fft_sp")

    conv_api._FFT_SP_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out1 = conv(u, h, None)
        out2 = conv(u, h, None)
    hits = [x for x in w if "fft_sp" in str(x.message)]
    assert len(hits) == 1, [str(x.message) for x in w]
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(fft_causal_conv(u, h, None)),
        rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_batch_spec_cp_seq_rule():
    """The rule-engine extension: batch_spec shards dim0 over data axes and
    dim1 over the cp axis when divisible; non-divisible seq replicates."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import batch_spec

    class FakeMesh:
        shape = {"data": 2, "model": 4}

    assert batch_spec(FakeMesh(), 2, 8, 32, cp_axis="model") == P("data", "model")
    assert batch_spec(FakeMesh(), 2, 8, 30, cp_axis="model") == P("data")
    assert batch_spec(FakeMesh(), 2, 8, 32, cp_axis=None) == P("data")
    # batch not divisible → replicated dim0, seq still shards
    assert batch_spec(FakeMesh(), 2, 3, 32, cp_axis="model") == P(None, "model")


# ------------------------------------------------ long-context smoke

@pytest.mark.slow
def test_cp_long_context_trains_where_unsharded_peak_is_larger():
    """A sequence length whose unsharded lowering needs a multiple of the
    cp step's per-device temp memory actually *trains* under cp_axis.  On
    CPU nothing truly OOMs, so the 'does not fit' claim is made the way
    the bench artifact records it: XLA buffer-assignment peak of the
    unsharded compile vs the executed cp compile."""
    out = run_subprocess("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.common.policy import FP32
        from repro.train import optim as O
        from repro.train import trainer as T

        cfg = ModelConfig(
            name="cp-long", family="test",
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=128, pattern=("hyena",),
            local_window=64, ssm_state=16, ssd_head_dim=16, rnn_width=64,
            hyena_filter_width=16, hyena_pos_dim=9,
        )
        B, L = 2, 8 * 4096   # 32K tokens, sharded 4K/chip over cp=8
        opt = O.AdamWConfig(lr=1e-3, warmup_steps=0)
        tok = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, 128)

        def peak(tcfg, mesh=None, execute=False):
            ectx = tcfg.apply_context(mesh=mesh)
            state, axes = T.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
            batch = {"tokens": tok}
            if mesh is not None:
                state = ectx.place(
                    state, ectx.train_state_shardings(axes, state))
                batch = {"tokens": jax.device_put(
                    tok, ectx.data_sharding(2, B, L))}
            step = jax.jit(T.make_train_step(cfg, tcfg))
            with ectx.scope():
                compiled = step.lower(state, batch).compile()
                mem = compiled.memory_analysis()
                p = int(mem.temp_size_in_bytes)
                if execute:
                    state, m = compiled(state, batch)
                    assert np.isfinite(float(m["loss"]))
            return p

        base = T.TrainConfig(optimizer=opt, remat=False, policy=FP32)
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        cp = dataclasses.replace(base, cp_axis="model")
        p_cp = peak(cp, mesh=mesh, execute=True)
        p_un = peak(base)  # lowered only — this is the one that OOMs for real
        print(f"peak unsharded={p_un} cp={p_cp} ratio={p_un/max(p_cp,1):.1f}")
        assert p_un > 2 * p_cp, (p_un, p_cp)
        print("LONGCTX-OK")
    """)
    assert "LONGCTX-OK" in out
