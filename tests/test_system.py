"""End-to-end behaviour tests for the paper's system."""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def run_example(script, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_quickstart_trains_and_generates():
    out = run_example("quickstart.py", "--steps", "15")
    assert "OK" in out
    # loss must have dropped below the ~5.55 uniform-over-bytes entropy
    losses = [float(l.split("loss ")[1].split(" ")[0])
              for l in out.splitlines() if "loss" in l]
    assert losses[-1] < losses[0]


def test_recall_example_checkpoint_resume(tmp_path):
    ck = str(tmp_path / "ck")
    out1 = run_example(
        "train_associative_recall.py", "--steps", "30", "--vocab", "12",
        "--seq", "32", "--ckpt", ck, "--ckpt-every", "15",
    )
    assert "accuracy" in out1
    out2 = run_example(
        "train_associative_recall.py", "--steps", "40", "--vocab", "12",
        "--seq", "32", "--ckpt", ck, "--ckpt-every", "15",
    )
    assert "resumed from step 30" in out2


def test_serve_example():
    out = run_example("serve_batched.py", "--new-tokens", "6")
    assert "OK" in out and "tok/s" in out


def test_hyena_vit_example():
    out = run_example("hyena_vit.py", "--steps", "35")
    assert "OK" in out


def test_hyena_learns_recall_better_than_chance():
    """System-level §4.1 claim: a 2-layer Hyena solves associative recall on
    held-out dictionaries far above chance."""
    import dataclasses
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data import synthetic
    from repro.models import lm
    from repro.train import optim as O
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    vocab = 12
    cfg = dataclasses.replace(
        get_config("hyena-153m").reduced(), vocab_size=16, n_layers=2
    )
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(rng, n=256, seq_len=32,
                                                  vocab=vocab)
    t_tokens, t_labels = synthetic.associative_recall(rng, n=128, seq_len=32,
                                                      vocab=vocab)
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=150,
                                weight_decay=0.0),
        remat=False,
    )
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    for _ in range(150):
        state, _ = step(state, batch)
    logits, _ = lm.forward(state["params"], cfg, jnp.asarray(t_tokens))
    acc = synthetic.eval_accuracy(np.asarray(logits, np.float32), t_labels)
    chance = 2.0 / vocab  # value space is vocab/2 symbols
    # container-scale budget (150 steps, ~2x chance on held-out
    # dictionaries under the trainer's default bf16 compute policy; 120
    # steps sat exactly on the bar); full separation needs the paper's
    # 200-epoch budget.
    assert acc > 1.5 * chance, f"recall acc {acc:.2f} vs chance {chance:.2f}"
