"""Multi-device serve parity: the continuous-batching engine on a 2×4
debug mesh must be token-identical to the single-device engine across the
randomized-schedule harness (ISSUE 4 acceptance; DESIGN.md §9).

Every case runs in a subprocess with 8 forced host devices (the
tests/test_distributed.py pattern) so the main pytest process keeps seeing
one device; the schedule driver itself is shared with the subprocess via
tests/serve_parity.py.  The fast tier pins fixed seeds; the ``slow``
property tier draws randomized schedules (nightly CI runs it with 8 forced
host devices so mesh parity doesn't rot between TPU runs).
"""
import os
import subprocess
import sys
import textwrap

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

# randomized schedules per subprocess in the slow tier: bounded separately
# from PROP_EXAMPLES (100 nightly examples × an 8-device pooled decode per
# step would blow the nightly budget; 12 schedules already cover arrivals,
# stops, and preemptions on both engines)
N_EXAMPLES = min(
    int(os.environ.get("PROP_EXAMPLES", "25")),
    int(os.environ.get("REPRO_DIST_SERVE_EXAMPLES", "12")),
)


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + HERE  # src + the shared driver
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_mesh_serve_token_identical_fixed_schedule():
    """Fast-tier pin: one fixed mixed schedule (arrivals + eviction) on
    hyena, 2×4 mesh vs single device, token-identical."""
    out = run_subprocess("""
        import serve_parity
        n = serve_parity.compare_schedule("hyena-153m", seed=1234)
        print("OK", n, "requests")
    """)
    assert "OK" in out


def _make_property(arch, n_data, n_model):
    def harness():
        out = run_subprocess(f"""
            import numpy as np
            import serve_parity
            rng = np.random.default_rng(7)
            for ex in range({N_EXAMPLES}):
                seed = int(rng.integers(0, 1 << 30))
                try:
                    serve_parity.compare_schedule(
                        "{arch}", seed, n_data={n_data}, n_model={n_model},
                    )
                except Exception as e:
                    raise AssertionError(
                        f"mesh serve parity failed on example {{ex}} "
                        f"(seed {{seed}}): {{e}}"
                    ) from e
            print("OK")
        """)
        assert "OK" in out

    harness.__name__ = (
        f"test_mesh_serve_randomized_{arch.replace('-', '_')}"
    )
    return pytest.mark.slow(harness)


# one arch per decode-cache family that shards differently: hyena (operand
# history + shared taps) on a 2×4 mesh, attention (KV ring + per-row RoPE
# cursors) on 4×2 — the reduced config's 2 KV heads must divide the model
# axis for the pool to actually shard
for _arch, _nd, _nm in (("hyena-153m", 2, 4), ("phi4-mini-3.8b", 4, 2)):
    _t = _make_property(_arch, _nd, _nm)
    globals()[_t.__name__] = _t
del _t


# ---------------------------------------------------------------- paged
#
# The paged engine's mesh parity is exact (same program both sides); the
# randomized plans additionally cover prefix forks, chunked prefill, block
# pressure, and radix chaos — shapes the dense compare_schedule never hits.

def test_mesh_paged_serve_fixed_schedule():
    """Fast-tier pin: one fixed randomized paged schedule on hyena, 2×4
    mesh vs meshless, token-identical with a genuinely sharded block
    pool."""
    out = run_subprocess("""
        import serve_parity
        n = serve_parity.compare_paged_mesh("hyena-153m", seed=1234)
        print("OK", n, "requests")
    """)
    assert "OK" in out


def _make_paged_property(arch, n_data, n_model):
    def harness():
        out = run_subprocess(f"""
            import numpy as np
            import serve_parity
            rng = np.random.default_rng(11)
            for ex in range({N_EXAMPLES}):
                seed = int(rng.integers(0, 1 << 30))
                try:
                    serve_parity.compare_paged_mesh(
                        "{arch}", seed, n_data={n_data}, n_model={n_model},
                    )
                except Exception as e:
                    raise AssertionError(
                        f"paged mesh serve parity failed on example {{ex}} "
                        f"(seed {{seed}}): {{e}}"
                    ) from e
            print("OK")
        """)
        assert "OK" in out

    harness.__name__ = (
        f"test_mesh_paged_serve_randomized_{arch.replace('-', '_')}"
    )
    return pytest.mark.slow(harness)


for _arch, _nd, _nm in (("hyena-153m", 2, 4), ("phi4-mini-3.8b", 4, 2)):
    _t = _make_paged_property(_arch, _nd, _nm)
    globals()[_t.__name__] = _t
del _t


# ---------------------------------------------------------------- chaos
#
# The serve fault contract on a mesh (DESIGN.md §13): the SAME chaos
# schedule — seeded NaN/Inf poisoning, transient errors, deadlines,
# cancellations — on a meshless vs a 2×4 mesh engine must produce
# identical terminal statuses AND tokens for every request (fault coins
# are drawn host-side from the schedule, never from device state).

def test_mesh_chaos_fixed_schedule():
    """Fast-tier pin: one fixed chaos schedule on hyena, mesh vs
    meshless, identical structured outcomes."""
    out = run_subprocess("""
        import serve_parity
        n = serve_parity.compare_chaos_mesh("hyena-153m", seed=7)
        print("OK", n, "requests")
    """)
    assert "OK" in out


def _make_chaos_property(arch, n_data, n_model):
    def harness():
        out = run_subprocess(f"""
            import numpy as np
            import serve_parity
            rng = np.random.default_rng(13)
            for ex in range({N_EXAMPLES}):
                seed = int(rng.integers(0, 1 << 30))
                try:
                    serve_parity.compare_chaos_mesh(
                        "{arch}", seed, n_data={n_data}, n_model={n_model},
                    )
                except Exception as e:
                    raise AssertionError(
                        f"mesh chaos parity failed on example {{ex}} "
                        f"(seed {{seed}}): {{e}}"
                    ) from e
            print("OK")
        """)
        assert "OK" in out

    harness.__name__ = (
        f"test_mesh_chaos_randomized_{arch.replace('-', '_')}"
    )
    return pytest.mark.slow(harness)


for _arch, _nd, _nm in (("hyena-153m", 2, 4), ("phi4-mini-3.8b", 4, 2)):
    _t = _make_chaos_property(_arch, _nd, _nm)
    globals()[_t.__name__] = _t
del _t
