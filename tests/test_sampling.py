"""Deterministic fixed-key sampling tests: greedy / temperature / top-k,
the exact-k tie-handling fix, and the per-slot vectorized path used by the
continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import NEG_INF, sample, sample_slots, top_k_mask


def test_greedy_ignores_key_and_temperature_zero():
    logits = jnp.asarray([[0.0, 10.0, 0.0], [5.0, 0.0, 4.9]])
    for seed in (0, 1, 2):
        got = sample(jax.random.PRNGKey(seed), logits)
        assert list(np.asarray(got)) == [1, 0]


def test_fixed_key_temperature_deterministic():
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 32))
    a = sample(jax.random.PRNGKey(7), logits, temperature=0.8, top_k=5)
    b = sample(jax.random.PRNGKey(7), logits, temperature=0.8, top_k=5)
    assert list(np.asarray(a)) == list(np.asarray(b))
    c = sample(jax.random.PRNGKey(8), logits, temperature=0.8)
    assert a.shape == c.shape  # different key may differ; shape contract


def test_top_k_mask_keeps_exactly_k_with_ties():
    """The old threshold (logits < kth) admitted every candidate tied at
    the kth value; the rank-based mask keeps exactly k, ties broken toward
    the lower token id."""
    logits = jnp.asarray([[1.0, 1.0, 1.0, 0.0],
                          [2.0, 3.0, 3.0, 3.0]])
    masked = np.asarray(top_k_mask(logits, 2))
    assert (masked[0] > NEG_INF / 2).sum() == 2
    assert (masked[1] > NEG_INF / 2).sum() == 2
    # stable tie-break: lowest ids among the tied survive
    assert list(np.nonzero(masked[0] > NEG_INF / 2)[0]) == [0, 1]
    assert list(np.nonzero(masked[1] > NEG_INF / 2)[0]) == [1, 2]
    # top_k = 0 keeps everything
    assert (np.asarray(top_k_mask(logits, 0)) > NEG_INF / 2).all()


def test_top_k_sampling_never_leaves_the_nucleus():
    logits = jnp.asarray([[1.0, 1.0, 1.0, 0.0]])
    for seed in range(64):
        tok = sample(jax.random.PRNGKey(seed), logits, temperature=1.0,
                     top_k=2)
        assert int(tok[0]) in (0, 1), f"seed {seed} escaped the top-2 set"


def test_sample_slots_matches_scalar_paths_per_row():
    """Each pool row reproduces the scalar `sample` result for its own
    (temperature, top_k, key) triple — greedy and sampled rows coexist."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 16))
    keys = jnp.stack([jax.random.PRNGKey(10 + i) for i in range(3)])
    temps = jnp.asarray([0.0, 0.7, 1.3])
    topks = jnp.asarray([0, 4, 0], jnp.int32)
    got = np.asarray(sample_slots(keys, logits, temps, topks))
    assert got[0] == int(np.argmax(np.asarray(logits[0])))
    for i in (1, 2):
        want = sample(keys[i], logits[i][None],
                      temperature=float(temps[i]), top_k=int(topks[i]))
        assert got[i] == int(want[0]), i


def test_sample_slots_per_slot_top_k():
    """Per-row k: row 0 truncates to its top-2, row 1 keeps everything."""
    logits = jnp.asarray([[5.0, 4.9, -10.0, -10.0],
                          [0.0, 0.0, 0.0, 10.0]])
    temps = jnp.asarray([1.0, 1.0])
    topks = jnp.asarray([2, 0], jnp.int32)
    for seed in range(32):
        keys = jnp.stack([jax.random.PRNGKey(seed)] * 2)
        got = np.asarray(sample_slots(keys, logits, temps, topks))
        assert got[0] in (0, 1)
