"""Registry conformance suite: every registered TokenMixer honors the shared
contract — teacher-forced forward vs. decode parity, cache shape/dtype
specs, metadata (state_bytes / flops) against measured shapes — and a new
mixer can be registered without touching blocks.py / lm.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.param import Ax, split_params
from repro.configs.base import ModelConfig
from repro.core.conv_api import (
    get_conv_backend,
    registered_conv_backends,
    resolve_conv_backend,
)
from repro.models import blocks, lm
from repro.models.mixer_api import (
    ApplyContext,
    TokenMixer,
    get_mixer,
    register_mixer,
    registered_mixers,
)

BUILTIN_MIXERS = (
    "attention", "local_attention", "hyena", "ssd", "rglru",
    "hyena_se", "hyena_mr", "hyena_li",
)


def small_cfg(mixer: str) -> ModelConfig:
    """A tiny ModelConfig exercising the named mixer."""
    return ModelConfig(
        name=f"conformance-{mixer}", family="test",
        n_layers=1, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=64, pattern=(mixer,), local_window=8,
        ssm_state=16, ssd_head_dim=16, rnn_width=32,
        hyena_filter_width=16, hyena_pos_dim=9,
        hyena_se_len=4, hyena_mr_support=8,
    )


def test_all_builtins_registered():
    names = set(registered_mixers())
    assert names >= set(BUILTIN_MIXERS), names


def test_unknown_mixer_raises_with_registered_list():
    with pytest.raises(ValueError, match="registered"):
        get_mixer("mamba3")


# ------------------------------------------------------------- conformance

@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_forward_decode_parity(mixer):
    """apply == prefill teacher-forced outputs; decode_step continues a
    prefilled cache exactly; decode-from-empty-cache matches apply."""
    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    B, L, L0 = 2, 12, 8
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))
    ctx = ApplyContext()

    y_apply = m.apply(params, mc, x, ctx)
    assert y_apply.shape == (B, L, cfg.d_model)
    assert np.isfinite(np.asarray(y_apply, np.float32)).all()

    # prefill over the full sequence is the teacher-forced forward
    y_pf, _ = m.prefill(params, mc, x, L, jnp.float32, ctx)
    np.testing.assert_allclose(
        np.asarray(y_pf, np.float32), np.asarray(y_apply, np.float32),
        rtol=2e-3, atol=2e-3,
    )

    # prefill a prefix, then decode the rest token-by-token
    assert m.supports_decode
    _, cache = m.prefill(params, mc, x[:, :L0], L, jnp.float32, ctx)
    for t in range(L0, L):
        y_t, cache = m.decode_step(params, mc, x[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(y_t, np.float32), np.asarray(y_apply[:, t], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=f"{mixer} decode step {t}",
        )

    # decode from an *empty* init_cache reproduces the whole sequence
    cache = m.init_cache(mc, B, L, jnp.float32)
    for t in range(L):
        y_t, cache = m.decode_step(params, mc, x[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(y_t, np.float32), np.asarray(y_apply[:, t], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=f"{mixer} cold decode step {t}",
        )


@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_cache_spec_stable_under_decode(mixer):
    """decode_step preserves the cache treedef and every leaf's shape/dtype
    (required for lax.scan over decode steps)."""
    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    B, L = 2, 8
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    cache = m.init_cache(mc, B, L, jnp.bfloat16)
    x_t = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model),
                            jnp.bfloat16)
    _, cache2 = m.decode_step(params, mc, x_t, cache)
    spec = lambda c: jax.tree_util.tree_map(lambda a: (a.shape, a.dtype), c)
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)
    assert spec(cache) == spec(cache2), mixer


@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_cache_slot_ops_conformance(mixer):
    """The serving slot contract: cache_slot_axes covers every cache key,
    cache_slice/cache_insert roundtrip one request's state between a pooled
    cache and a batch-1 cache, and cache_reset zeroes exactly one slot."""
    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    B, L = 3, 8
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    xa = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))
    xb = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, L, cfg.d_model))
    _, pool = m.prefill(params, mc, xa, L, jnp.float32, ApplyContext())
    _, one = m.prefill(params, mc, xb, L, jnp.float32, ApplyContext())
    axes = m.cache_slot_axes(mc)
    assert set(axes) <= set(pool), (set(axes), set(pool))
    assert set(m.init_cache(mc, B, L, jnp.float32)) <= set(pool)

    # slice(insert(pool, s, one), s) == one, for every per-slot leaf
    slot = 1
    pool2 = m.cache_insert(mc, pool, slot, one)
    back = m.cache_slice(mc, pool2, slot)
    for k in pool:
        np.testing.assert_allclose(
            np.asarray(back[k], np.float32), np.asarray(one[k], np.float32),
            err_msg=f"{mixer}.{k}",
        )
        # the other slots are untouched by the insert
        ax = axes.get(k, 0)
        if ax >= 0:
            np.testing.assert_allclose(
                np.asarray(jnp.take(pool2[k], 0, axis=ax), np.float32),
                np.asarray(jnp.take(pool[k], 0, axis=ax), np.float32),
                err_msg=f"{mixer}.{k} slot 0 disturbed",
            )

    # reset zeroes exactly the target slot; shared leaves survive
    pool3 = m.cache_reset(mc, pool2, slot)
    for k in pool3:
        ax = axes.get(k, 0)
        if ax < 0:
            np.testing.assert_array_equal(
                np.asarray(pool3[k]), np.asarray(pool2[k]), err_msg=k
            )
        else:
            assert float(jnp.sum(jnp.abs(
                jnp.take(pool3[k], slot, axis=ax).astype(jnp.float32)
            ))) == 0.0, f"{mixer}.{k} not reset"
            np.testing.assert_array_equal(
                np.asarray(jnp.take(pool3[k], 2, axis=ax)),
                np.asarray(jnp.take(pool2[k], 2, axis=ax)),
                err_msg=f"{mixer}.{k} slot 2 disturbed by reset",
            )

    # an inserted slot decodes exactly like the standalone batch-1 cache
    x_t = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (B, cfg.d_model))
    y_pool, _ = m.decode_step(params, mc, x_t, pool2)
    y_one, _ = m.decode_step(params, mc, x_t[slot : slot + 1], one)
    np.testing.assert_allclose(
        np.asarray(y_pool[slot], np.float32),
        np.asarray(y_one[0], np.float32), rtol=1e-4, atol=1e-4,
        err_msg=f"{mixer}: pooled decode != standalone decode",
    )


@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_cache_shard_axes_conformance(mixer):
    """The rule-driven cache-sharding spec (DESIGN.md §9): every named key
    exists in the serving cache with a rank-matching tuple of known logical
    names, and the rule engine resolves the spec on a production-shaped
    mesh without touching the slot dim or cursors."""
    from repro.distributed.sharding import TP_RULES, resolve_spec
    from jax.sharding import PartitionSpec as P

    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    spec = m.cache_shard_axes(mc)
    cache = jax.eval_shape(lambda: m.init_cache(mc, 2, 16, jnp.bfloat16))
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    full = jax.eval_shape(
        lambda: m.prefill(params, mc, jnp.zeros((2, 8, cfg.d_model)), 16,
                          jnp.bfloat16, ApplyContext())[1]
    )
    assert set(spec) <= set(full), (mixer, set(spec) - set(full))
    known = set(TP_RULES) | {None}

    class FakeMesh:  # debug-mesh shape: reduced configs have few heads
        shape = {"data": 2, "model": 2}

    # per-slot cursors carry no spec at all: they must replicate (every
    # chip owns every slot's RoPE position / validity mask)
    assert "t" not in spec, (mixer, spec)
    slot_axes = m.cache_slot_axes(mc)
    for k, ax in spec.items():
        leaf = full[k]
        assert len(ax) == leaf.ndim, (mixer, k, ax, leaf.shape)
        assert set(ax) <= known, (mixer, k, set(ax) - known)
        p = resolve_spec(ax, leaf.shape, FakeMesh())
        # the slot dim may shard over the data axes (data-parallel
        # request ownership) but never over 'model' — the tensor-parallel
        # axis belongs to heads/channels
        slot_dim = slot_axes.get(k, 0)
        if slot_dim >= 0 and len(p) > slot_dim:
            entry = p[slot_dim]
            names = entry if isinstance(entry, tuple) else (
                (entry,) if entry else ())
            assert "model" not in names, (mixer, k, p)
    # every decode-capable builtin shards at least one cache leaf over the
    # model axis — serving caches scale with TP, not per-chip replication
    resolved = [
        resolve_spec(ax, full[k].shape, FakeMesh()) for k, ax in spec.items()
    ]
    assert any("model" in jax.tree_util.tree_leaves(list(p)) or
               any(e == "model" for e in p) for p in resolved), (
        mixer, resolved
    )


def _tree_bytes(tree) -> int:
    return sum(
        int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(tree)
    )


@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_state_bytes_matches_measured_cache(mixer):
    """state_bytes metadata == the byte count of the *serving* cache — the
    prefill-populated one, which for hyena also carries the fp32 decode
    filter taps — at batch 1 with the bf16 cache dtype.  No drift between
    the capability tables and the real cache layout."""
    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    for max_len in (16, 64):
        x = jnp.zeros((1, 8, cfg.d_model))
        struct = jax.eval_shape(
            lambda x: m.prefill(params, mc, x, max_len, jnp.bfloat16,
                                ApplyContext())[1], x
        )
        assert m.state_bytes(cfg, max_len) == _tree_bytes(struct), (
            mixer, max_len
        )
        # the empty init_cache never exceeds the populated serving cache
        empty = jax.eval_shape(
            lambda: m.init_cache(mc, 1, max_len, jnp.bfloat16)
        )
        assert _tree_bytes(empty) <= m.state_bytes(cfg, max_len)


@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_flops_metadata_sane(mixer):
    """flops metadata scales with L and covers at least one mul+add per
    mixer parameter per token (every dense weight touches every token)."""
    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    L = 64
    f1, f2 = m.flops(cfg, L), m.flops(cfg, 2 * L)
    assert f1 > 0 and np.isfinite(f1)
    assert f2 >= 2 * f1  # at least linear in L
    n_params = sum(
        int(np.prod(leaf.shape))
        for leaf in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0), mc))
        )
    )
    assert f1 >= L * n_params, (mixer, f1, L * n_params)


def test_local_attention_state_is_windowed():
    """Capability metadata reflects the ring buffer: local attention state
    stops growing at the window size."""
    cfg = small_cfg("local_attention")
    m = get_mixer("local_attention")
    assert m.state_bytes(cfg, 1 << 20) == m.state_bytes(cfg, cfg.local_window)
    assert get_mixer("attention").state_bytes(cfg, 128) > \
        get_mixer("attention").state_bytes(cfg, 64)


# ------------------------------------------------- extension without edits

@register_mixer
class _ToyMixer(TokenMixer):
    """A per-channel gain — registered by the *test* to prove that adding a
    mixer touches zero dispatch sites in blocks.py / lm.py."""

    name = "toy_gain"

    def make_config(self, cfg):
        return cfg.d_model

    def init(self, key, d):
        return {"gain": Ax(jnp.ones((d,), jnp.float32), ("embed",))}

    def apply(self, params, d, h, ctx):
        return h * params["gain"].astype(h.dtype)

    def init_cache(self, d, batch, max_len, dtype):
        return {"t": jnp.zeros((), jnp.int32)}

    def prefill(self, params, d, h, max_len, dtype, ctx):
        return self.apply(params, d, h, ctx), {"t": jnp.asarray(h.shape[1], jnp.int32)}

    def decode_step(self, params, d, h_t, cache):
        return h_t * params["gain"].astype(h_t.dtype), {"t": cache["t"] + 1}

    def state_bytes(self, cfg, max_len):
        return 4

    def flops(self, cfg, L):
        return 2.0 * L * cfg.d_model


def test_new_mixer_runs_through_lm_without_dispatch_edits():
    cfg = dataclasses.replace(
        small_cfg("attention"), name="toy-arch", pattern=("toy_gain",),
        n_layers=2,
    )
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits, _ = lm.forward(params, cfg, tokens)
    assert logits.shape == (2, 16, cfg.vocab_size)
    caches = lm.init_caches(cfg, 2, 8, dtype=jnp.float32)
    lg, caches = lm.decode_step(params, cfg, tokens[:, 0], caches)
    assert lg.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all()
    # blocks-level helpers resolve it too
    assert blocks.mixer_config(cfg, "toy_gain") == cfg.d_model


def test_hyena_prefill_honors_ctx_conv_backend():
    """The serving path's backend override reaches the prompt long convs:
    prefill under the O(L²) oracle matches prefill under the default FFT."""
    cfg = small_cfg("hyena")
    m = get_mixer("hyena")
    mc = m.make_config(cfg)
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    y_fft, c_fft = m.prefill(params, mc, x, 12, jnp.float32, ApplyContext())
    y_dir, c_dir = m.prefill(
        params, mc, x, 12, jnp.float32, ApplyContext(conv_backend="direct")
    )
    np.testing.assert_allclose(np.asarray(y_fft), np.asarray(y_dir),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(c_fft["long"]),
                               np.asarray(c_dir["long"]), rtol=2e-3, atol=2e-3)


def test_ctx_mesh_override_matches_ambient():
    """ApplyContext.mesh is honored by the lm entry points: running under an
    explicit 1x1 mesh override matches the meshless run."""
    cfg = small_cfg("hyena")
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    want, _ = lm.forward(params, cfg, tokens)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got, _ = lm.forward(params, cfg, tokens, ctx=ApplyContext(mesh=mesh))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- conv backend API

def test_conv_backends_agree_on_small_input():
    B, L, D = 2, 32, 4
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
    skip = jax.random.normal(jax.random.PRNGKey(2), (D,))
    want = get_conv_backend("fft_local")(u, h, skip)
    for name, backend in registered_conv_backends().items():
        got = backend(u, h, skip)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-3,
            err_msg=name,
        )


def test_resolve_conv_backend_env_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_CONV_BACKEND", raising=False)
    assert resolve_conv_backend() == "fft"
    monkeypatch.setenv("REPRO_CONV_BACKEND", "blockfft")
    assert resolve_conv_backend() == "blockfft"
    assert resolve_conv_backend("direct") == "direct"  # override beats env
    monkeypatch.setenv("REPRO_CONV_BACKEND", "cufft")
    # the error names the bad backend, where it came from, and the sorted
    # registered list — a typo'd env var is diagnosable from the message
    with pytest.raises(
        ValueError,
        match=r"unknown conv backend 'cufft' \(from \$REPRO_CONV_BACKEND\)",
    ):
        resolve_conv_backend()
    with pytest.raises(ValueError, match="blockfft_overlap"):
        resolve_conv_backend("no-such-backend")


def test_backend_length_constraint():
    direct = get_conv_backend("direct")
    with pytest.raises(ValueError, match="supports L"):
        direct.validate_len(1 << 20)


def test_pattern_validated_at_config_registration():
    from repro.configs.base import register

    with pytest.raises(ValueError, match="registered"):
        register(dataclasses.replace(
            small_cfg("attention"), name="bad-arch", pattern=("atention",)
        ))


@pytest.mark.parametrize("mixer", BUILTIN_MIXERS)
def test_cache_page_axes_conformance(mixer):
    """The paging contract (mixer_api.cache_page_axes): every named key
    exists in the cache on the max_len grid with its time axis exactly one
    past the slot axis; those leaves really are append-only (positions
    below the cursor never move once written); and decode tolerates
    arbitrary garbage at positions >= t — the property that lets the paged
    allocator map unwritten table entries to a recycled trash block."""
    cfg = small_cfg(mixer)
    m = get_mixer(mixer)
    mc = m.make_config(cfg)
    spec = m.cache_page_axes(mc)
    slots = m.cache_slot_axes(mc)
    B, L, L0 = 2, 12, 6
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))
    _, cache = m.prefill(params, mc, x[:, :L0], L, jnp.float32,
                         ApplyContext())
    for k, ax in spec.items():
        assert k in cache, (mixer, k)
        assert slots.get(k, 0) >= 0, (mixer, k, "paged leaf must be per-slot")
        assert ax == slots.get(k, 0) + 1, (mixer, k, ax)
        assert cache[k].shape[ax] == L, (mixer, k, cache[k].shape)
    if not spec:
        return  # windowed / recurrent mixers: all state pinned

    def time_slice(leaf, ax, lo, hi):
        idx = [slice(None)] * leaf.ndim
        idx[ax] = slice(lo, hi)
        return np.asarray(leaf[tuple(idx)], np.float32)

    def corrupt(leaf, ax, start):
        pos = jnp.arange(leaf.shape[ax]).reshape(
            [leaf.shape[ax] if d == ax else 1 for d in range(leaf.ndim)]
        )
        return jnp.where(pos >= start, jnp.asarray(37.5, leaf.dtype), leaf)

    # garbage past the cursor must be invisible to decode (it is either
    # masked or overwritten at the write position before any read)
    dirty = {
        k: corrupt(v, spec[k], L0) if k in spec else v
        for k, v in cache.items()
    }
    clean, c, d = cache, cache, dirty
    for t in range(L0, L):
        y_c, c = m.decode_step(params, mc, x[:, t], c)
        y_d, d = m.decode_step(params, mc, x[:, t], d)
        np.testing.assert_allclose(
            np.asarray(y_c, np.float32), np.asarray(y_d, np.float32),
            rtol=1e-6, atol=1e-6,
            err_msg=f"{mixer} step {t}: garbage past the cursor leaked",
        )
        # append-only: everything before this step's write position is
        # byte-stable across the step
        for k, ax in spec.items():
            np.testing.assert_array_equal(
                time_slice(c[k], ax, 0, t), time_slice(clean[k], ax, 0, t),
                err_msg=f"{mixer}.{k} rewrote history at step {t}",
            )
        clean = {k: v for k, v in c.items()}


def test_cache_page_axes_lm_collector_validates_adjacency():
    """lm.cache_page_axes mirrors the cache tree with the paged time axis
    (shifted for scan-stacked groups) or -1, and rejects specs whose time
    axis is not slot + 1."""
    cfg = small_cfg("attention")
    m = get_mixer("attention")
    mc = m.make_config(cfg)
    params, _ = split_params(m.init(jax.random.PRNGKey(0), mc))
    cache = m.prefill(params, mc, jnp.zeros((1, 4, cfg.d_model)), 8,
                      jnp.float32, ApplyContext())[1]
    caches = {"groups": [cache]}
    axes = lm.cache_page_axes(cfg, caches)
    page_spec = m.cache_page_axes(mc)
    for k in cache:
        want = page_spec[k] + 1 if k in page_spec else -1  # stacked shift
        assert axes["groups"][0][k] == want, (k, axes["groups"][0][k])

    class BadMixer(type(m)):
        name = "bad-paging"

        def cache_page_axes(self, mc):
            return {"k": 3}  # k's slot axis is 0 -> time axis must be 1

    import unittest.mock as mock

    import repro.models.mixer_api as mixer_api

    with mock.patch.object(mixer_api, "get_mixer",
                           lambda name: BadMixer()):
        with pytest.raises(ValueError, match="slot axis"):
            lm.cache_page_axes(cfg, caches)
