"""Table 4.4 reproduction: total-FLOP comparison, GPT vs Hyena-2 at matched
scale and L=2048 — the paper's "matching perplexity with 20% less compute"
claim rests on this accounting.  We evaluate the paper's own FLOP model
(App. A.2) and cross-check the layer FLOPs against XLA cost_analysis of a
single compiled block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.flops import gpt_layer_flops, hyena_layer_flops, lm_total_flops


def run(rows):
    L = 2048
    # paper pairs (Table 4.4 / A.4): GPT-355M (24L? — use 355M config
    # d=1024, 24 layers, ffn 4096) vs Hyena-2 355M (36L, d=1024, ffn 2048)
    gpt = lm_total_flops(gpt_layer_flops(1024, 4096, L), 24, 1024, 50257, L)
    hy = lm_total_flops(hyena_layer_flops(1024, 2048, L, order=2), 36, 1024,
                        50257, L)
    ratio = hy / gpt
    rows.append(("table4.4/flops_ratio_hyena355m_vs_gpt355m", 0.0,
                 f"{ratio:.3f}"))
    # paper: 3.93e19 / 4.77e19 = 0.824 for the 15B-token run
    rows.append(("table4.4/paper_reported_ratio", 0.0, f"{3.93/4.77:.3f}"))

    # 125M-scale pair
    gpt125 = lm_total_flops(gpt_layer_flops(768, 3072, L), 12, 768, 50257, L)
    hy153 = lm_total_flops(hyena_layer_flops(864, 1728, L, order=2), 18, 864,
                           50257, L)
    rows.append(("table4.4/flops_ratio_hyena153m_vs_gpt125m", 0.0,
                 f"{hy153/gpt125:.3f}"))

    # cross-check one hyena block against XLA cost analysis
    from repro.common.param import split_params
    from repro.core import HyenaConfig, FilterConfig
    from repro.core.operator import init_hyena, hyena_operator

    D, Lc = 256, 1024
    cfg = HyenaConfig(d_model=D, order=2,
                      filter=FilterConfig(d_model=D, order=2))
    params, _ = split_params(init_hyena(jax.random.PRNGKey(0), cfg))
    u = jax.ShapeDtypeStruct((1, Lc, D), jnp.float32)
    comp = jax.jit(lambda p, u: hyena_operator(p, cfg, u)).lower(params, u).compile()
    xla_flops = comp.cost_analysis().get("flops", float("nan"))
    model = hyena_layer_flops(D, 0, Lc, order=2) - 2 * 2 * D * 0 * Lc
    rows.append(("table4.4/xla_vs_model_flops_one_block", 0.0,
                 f"xla={xla_flops:.3g};model={model:.3g}"))
    return rows
