"""Context-parallel training throughput + memory: cp=1 vs cp=P at fixed
per-chip tokens (weak scaling — the whole point of cp is that the *global*
sequence grows with the mesh while per-chip activation bytes stay flat).

Two measured rows plus one analysis row:

  * ``train/cp1``  — single-device train step at L = tokens_per_chip.
  * ``train/cpP``  — the same model, L = tokens_per_chip · P, sequence
    sharded over the cp axis of a (1, P) mesh through the full
    ``make_train_step`` path (fft_sp conv VJP, ring attention if the
    pattern has any, halo-exchanged targets).
  * ``train/unsharded_at_cpP_len`` — NOT executed: the single-device step
    *lowered* at the cp=P length, so the artifact records the estimated
    peak (temp) bytes the cp run avoids.  At real lengths this is the
    configuration that OOMs; here it documents the ratio.

Plus the reversible-substrate pair (DESIGN.md §15), lowered-only at a
deeper stack (``--rev-depth``, default 16 — the regime where depth-resident
activations dominate):

  * ``train/standard_deep``   — remat'd single-stream scan at depth D.
  * ``train/reversible_deep`` — the same model with ``reversible=True``:
    the coupling custom_vjp's residuals are O(1) in depth, so
    ``peak_bytes`` must come out *below* the standard row (asserted by the
    CI fast tier), and ``compile_s`` records what the reconstruct-and-
    recompute backward costs at trace/compile time.

The deep pair uses its own ``--rev-pattern`` (default ``hyena``, the
paper's operator): attention rows would dominate the peak with
depth-independent L^2 score temps and mask the depth-resident carry the
pair exists to measure; hyena's O(L log L) FFT temps keep it visible
(standard grows linearly in depth, reversible stays flat).

Peak-memory numbers come from ``compiled.memory_analysis()`` (XLA's
buffer-assignment peak; ``temp_size_in_bytes``); every row also carries
``compile_s`` (wall seconds for ``lowered.compile()``).  CPU-to-CPU
comparable only — rerun on TPU for real numbers, like the other BENCH
artifacts.

    PYTHONPATH=src python benchmarks/bench_train.py --json BENCH_train.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (cp axis size)")
    ap.add_argument("--tokens-per-chip", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--pattern", default="hyena",
                    help="comma-separated mixer pattern")
    ap.add_argument("--steps", type=int, default=3,
                    help="timed steps after the compile step")
    ap.add_argument("--rev-depth", type=int, default=16,
                    help="layer count for the reversible-vs-standard pair")
    ap.add_argument("--rev-seq-len", type=int, default=2048,
                    help="sequence length for the reversible-vs-standard pair")
    ap.add_argument("--rev-pattern", default="hyena",
                    help="mixer pattern for the reversible-vs-standard pair")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}",
    )
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.common.policy import FP32
    from repro.configs.base import ModelConfig
    from repro.train import optim as O
    from repro.train.trainer import (
        TrainConfig, init_train_state, make_train_step,
    )

    P_sz = args.devices
    pattern = tuple(args.pattern.split(","))
    cfg = ModelConfig(
        name="bench-cp", family="bench",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=4, n_kv_heads=2, head_dim=args.d_model // 4,
        d_ff=2 * args.d_model, vocab_size=256, pattern=pattern,
        local_window=256, ssm_state=16, ssd_head_dim=16,
        rnn_width=args.d_model, hyena_filter_width=16, hyena_pos_dim=9,
    )
    opt = O.AdamWConfig(lr=1e-3, warmup_steps=0)
    rows = []
    errors = []

    def run_case(name, tcfg, L, mesh=None, execute=True, model_cfg=None):
        mcfg = model_cfg or cfg
        ectx = tcfg.apply_context(mesh=mesh)
        state, axes = init_train_state(jax.random.PRNGKey(0), mcfg, tcfg)
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, L), 0, mcfg.vocab_size
        )
        # no labels on purpose: exercises the in-step halo-exchanged
        # next-token targets under cp
        batch = {"tokens": tokens}
        if mesh is not None:
            state = ectx.place(state, ectx.train_state_shardings(axes, state))
            batch = {
                k: jax.device_put(
                    v, ectx.data_sharding(v.ndim, v.shape[0], v.shape[1])
                )
                for k, v in batch.items()
            }
        step = jax.jit(make_train_step(mcfg, tcfg))
        with ectx.scope():
            lowered = step.lower(state, batch)
            tc0 = time.perf_counter()
            compiled = lowered.compile()
            compile_s = time.perf_counter() - tc0
            mem = compiled.memory_analysis()
            peak = int(getattr(mem, "temp_size_in_bytes", 0)) if mem else None
            if not execute:
                return {
                    "name": name, "seq_len": L, "cp": P_sz if mesh else 1,
                    "tok_s": None, "peak_bytes": peak,
                    "compile_s": round(compile_s, 3), "executed": False,
                }
            state, m = compiled(state, batch)  # compile+warm
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(args.steps):
                state, m = compiled(state, batch)
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / args.steps
        toks = args.batch * L
        return {
            "name": name, "seq_len": L, "cp": P_sz if mesh else 1,
            "tok_s": toks / dt, "step_ms": dt * 1e3,
            "peak_bytes": peak, "compile_s": round(compile_s, 3),
            "loss": float(m["loss"]), "executed": True,
        }

    base = TrainConfig(optimizer=opt, remat=False, policy=FP32)
    L1 = args.tokens_per_chip
    Lbig = args.tokens_per_chip * P_sz
    try:
        rows.append(run_case("train/cp1", base, L1))
    except Exception as e:  # pragma: no cover
        errors.append(f"train/cp1: {e!r}")
    try:
        mesh = jax.make_mesh((1, P_sz), ("data", "model"))
        cp = dataclasses.replace(base, cp_axis="model")
        rows.append(run_case("train/cpP", cp, Lbig, mesh=mesh))
    except Exception as e:  # pragma: no cover
        errors.append(f"train/cpP: {e!r}")
    try:
        rows.append(
            run_case("train/unsharded_at_cpP_len", base, Lbig, execute=False)
        )
    except Exception as e:  # pragma: no cover
        errors.append(f"train/unsharded_at_cpP_len: {e!r}")

    # reversible-vs-standard at depth where activations dominate: lowered
    # only (the numbers of record are peak temp bytes + compile seconds)
    deep_cfg = dataclasses.replace(
        cfg, n_layers=args.rev_depth,
        pattern=tuple(args.rev_pattern.split(",")),
    )
    std_deep = TrainConfig(optimizer=opt, remat=True, policy=FP32)
    rev_deep = dataclasses.replace(std_deep, reversible=True)
    try:
        rows.append(run_case("train/standard_deep", std_deep,
                             args.rev_seq_len, execute=False,
                             model_cfg=deep_cfg))
    except Exception as e:  # pragma: no cover
        errors.append(f"train/standard_deep: {e!r}")
    try:
        rows.append(run_case("train/reversible_deep", rev_deep,
                             args.rev_seq_len, execute=False,
                             model_cfg=deep_cfg))
    except Exception as e:  # pragma: no cover
        errors.append(f"train/reversible_deep: {e!r}")

    for r in rows:
        tok = "-" if r["tok_s"] is None else f"{r['tok_s']:12.0f}"
        pk = "-" if r["peak_bytes"] is None else f"{r['peak_bytes']:>14d}"
        print(f"{r['name']:28s} L={r['seq_len']:>7d} cp={r['cp']} "
              f"tok/s={tok} peak_bytes={pk}")
    if args.json:
        # schema 2: one scalar headline (the executed context-parallel
        # step's throughput) for perf-trajectory tooling
        cpP = next((r for r in rows if r["name"] == "train/cpP"), None)
        std = next((r for r in rows if r["name"] == "train/standard_deep"),
                   None)
        rev = next((r for r in rows if r["name"] == "train/reversible_deep"),
                   None)
        rev_ratio = (
            None if not (std and rev and std.get("peak_bytes")
                         and rev.get("peak_bytes") is not None)
            else round(rev["peak_bytes"] / std["peak_bytes"], 4)
        )
        artifact = {
            "schema": 2,
            "summary": {
                "train": {
                    "metric": "train/cpP",
                    "value": (
                        None if cpP is None or cpP["tok_s"] is None
                        else round(cpP["tok_s"], 1)
                    ),
                    "unit": "tok_s",
                },
                "reversible": {
                    "metric": "train/reversible_deep peak over standard",
                    "value": rev_ratio,
                    "unit": "peak_bytes_ratio",
                },
            },
            "rev_depth": args.rev_depth,
            "rev_pattern": args.rev_pattern.split(","),
            "device": jax.devices()[0].platform,
            "devices": P_sz,
            "tokens_per_chip": args.tokens_per_chip,
            "pattern": list(pattern),
            "note": "CPU forced-host-device numbers; CI-to-CI comparable "
                    "only. peak_bytes = XLA buffer-assignment temp size.",
            "rows": rows,
            "errors": errors,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
