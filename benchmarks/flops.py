"""FLOP models (paper App. A.2) — Table 4.4's accounting, reused by the
benchmark harness and the roofline report.

GPT per layer (forward, ×2 for mul+add; ×3 for fwd+bwd):
  attention: qkvo projections 4·D²·L + attention matrix 2·D·L² (non-param)
  mlp: 2·D·d_ff·L
Hyena per layer (order N):
  projections (N+1)·D²·L ; short conv (N+1)·D·L·3 ;
  FFTConv 5·N·D·L·log2(L) ; output D²·L
"""
from __future__ import annotations

import math


def gpt_layer_flops(d_model: int, d_ff: int, L: int) -> float:
    proj = 4 * d_model * d_model * L
    attn = 2 * d_model * L * L
    mlp = 2 * d_model * d_ff * L
    return 2.0 * (proj + attn + mlp)


def hyena_layer_flops(d_model: int, d_ff: int, L: int, order: int = 2,
                      short_len: int = 3) -> float:
    proj = (order + 1) * d_model * d_model * L
    short = (order + 1) * d_model * L * short_len
    fftconv = 5 * order * d_model * L * math.log2(max(L, 2))
    out = d_model * d_model * L
    mlp = 2 * d_model * d_ff * L
    return 2.0 * (proj + short + fftconv + out + mlp)


def lm_total_flops(layer_flops: float, n_layers: int, d_model: int,
                   vocab: int, L: int, train: bool = True) -> float:
    head = 2.0 * d_model * vocab * L
    total = layer_flops * n_layers + head
    return total * 3.0 if train else total  # bwd = 2x fwd


# ------------------------------------------------- registry-driven accounting

def mixer_flops(mixer: str, cfg, L: int) -> float:
    """Forward FLOPs of one named mixer layer via its registry metadata
    (``TokenMixer.flops``) — the same tables the conformance suite checks
    against measured parameter shapes."""
    from repro.models.mixer_api import get_mixer

    return get_mixer(mixer).flops(cfg, L)


def lm_flops_from_registry(cfg, L: int, train: bool = True) -> float:
    """Total step FLOPs for a ``ModelConfig``: per-pattern mixer flops from
    the TokenMixer registry + channel-mixer + head.  Unlike the hand
    formulas above, this covers arbitrary hybrid patterns (e.g.
    RecurrentGemma's rglru/rglru/local_attention) with no per-arch math."""
    plen = len(cfg.pattern)
    per_pattern = sum(mixer_flops(m, cfg, L) for m in cfg.pattern)
    n_groups = cfg.n_layers // plen
    total = per_pattern * n_groups
    for m in cfg.pattern[: cfg.n_layers % plen]:  # unstacked tail layers
        total += mixer_flops(m, cfg, L)
    if cfg.d_ff > 0:
        # gated MLPs (swiglu/geglu) have an extra gate matmul per layer
        n_mats = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        mlp = 2.0 * n_mats * cfg.d_model * cfg.d_ff * L
        if cfg.moe and cfg.n_experts:
            mlp *= cfg.top_k  # active experts per token
        total += mlp * cfg.n_layers
    total += 2.0 * cfg.d_model * cfg.vocab_size * L  # head
    return total * 3.0 if train else total  # bwd = 2x fwd
