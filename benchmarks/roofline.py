"""Three-term roofline model for TPU v5e from dry-run artifacts.

  compute    = FLOPs_per_chip / 197 TFLOP/s (bf16 MXU)
  memory     = HBM bytes_per_chip / 819 GB/s
  collective = collective bytes_per_chip / 50 GB/s (ICI per-link)

Artifacts store *per-chip* numbers (the SPMD-partitioned module is the
per-device program), so term = per_chip / per_chip_rate — algebraically the
same as the global form global/(chips × rate).  The scan-body undercount is
corrected by the depth-probe extrapolation recorded per cell (dryrun.py).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link


def load_cells(art_dir: str = "artifacts/dryrun/pod16x16") -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            cells.append(r)
    return cells


def terms(cell: Dict) -> Optional[Dict]:
    src = cell.get("extrapolated") or cell.get("full", {})
    flops = src.get("flops")
    byt = src.get("bytes_accessed")
    # depth-probe extrapolation can go non-monotone when the partitioner
    # picks different strategies at depth 1 vs 2 (seen on recurrentgemma
    # long_500k) — fall back to the full compile, flagged.
    probe_invalid = any(
        v is not None and v < 0
        for v in [flops, byt, *list((src.get("collectives") or {}).values())]
    )
    if flops is None or probe_invalid:
        ca = cell.get("full", {}).get("cost_analysis", {})
        flops, byt = ca.get("flops"), ca.get("bytes_accessed")
        src = cell.get("full", {})
    coll = src.get("collectives") or cell.get("full", {}).get("collectives", {})
    coll_bytes = sum(v for v in coll.values() if v)
    if flops is None:
        return None
    t_compute = flops / PEAK_FLOPS
    t_memory = (byt or 0) / HBM_BW
    t_coll = coll_bytes / ICI_BW
    ideal = max(t_compute, t_memory, t_coll, 1e-12)
    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]
    chips = cell.get("chips", 256)
    model_flops_per_chip = cell.get("model_flops", 0) / chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "ideal_step_s": ideal,
        "dominant": dominant,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else None,
        "mfu_bound": model_flops_per_chip / (PEAK_FLOPS * ideal),
        "collective_breakdown": coll,
    }
