"""Kernel micro-bench (§4.4 supplement): interpret-mode correctness-path
timing of each Pallas kernel vs its jnp oracle, plus the conv-backend
comparison (fft vs blockfft vs toeplitz) that drives the §Perf iteration.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(rows):
    from repro.core.blockfft import blockfft_causal_conv
    from repro.core.fftconv import fft_causal_conv
    from repro.kernels import ref

    B, L, D = 2, 2048, 64
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
    fft_t = _time(jax.jit(fft_causal_conv), u, h)
    blk_t = _time(jax.jit(blockfft_causal_conv), u, h)
    rows.append(("kernels/fftconv_L2048", fft_t, "xla_fft"))
    rows.append(("kernels/blockfft_L2048", blk_t, "matmul_dft"))

    g = jax.random.normal(jax.random.PRNGKey(2), (D,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(3), (B * L, D))
    rn_t = _time(jax.jit(lambda x, g: ref.rmsnorm(x, g)), x, g)
    rows.append(("kernels/rmsnorm_ref", rn_t, "oracle"))
    return rows
