"""Kernel micro-bench (§4.4 supplement): interpret-mode correctness-path
timing of each Pallas kernel vs its jnp oracle, plus the conv-backend
comparison (fft vs blockfft vs toeplitz) that drives the §Perf iteration.

The gated rows measure the tentpole fusion directly: ``*_gated_fused`` runs
``backend(u, h, skip, gate)`` (gate inside the conv's elementwise epilogue /
Pallas accumulator), ``*_gated_unfused`` runs the pre-fusion schedule
``gate * backend(u, h, skip)`` — one extra full-tensor elementwise pass per
call, i.e. per Hyena order.  The delta is the acceptance artifact written to
``BENCH_conv.json`` by ``benchmarks/run.py --json`` (interpret/CPU numbers
in CI; re-run on TPU for real ones).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


# headline row for the artifact's schema-2 summary block (benchmarks/run.py);
# blockfft is the one timed backend present on every platform (fft/fft_sp
# skip without a mesh, toeplitz skips off-TPU)
HEADLINE = "kernels/conv_blockfft_gated_fused_L2048"


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))  # compile + warm-up
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # min-of-iters: microbench noise floor, not mean


def run(rows):
    from repro.core.conv_api import registered_conv_backends
    from repro.kernels import ref

    B, L, D = 2, 2048, 64
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
    skip = jax.random.normal(jax.random.PRNGKey(4), (D,)) * 0.1
    gate = jax.random.normal(jax.random.PRNGKey(5), (B, L, D))
    # conv-backend comparison straight off the registry: new backends show
    # up here (and in the §Perf iteration) with zero bench edits.
    from repro.distributed.ctx import current_mesh

    for name, backend in sorted(registered_conv_backends().items()):
        if backend.oracle or (backend.max_len and L > backend.max_len):
            continue  # O(L²) references are not a timing row at L=2048
        if backend.requires_pallas and jax.default_backend() != "tpu":
            continue  # interpret-mode timing is meaningless
        if backend.mesh_aware and current_mesh() is None:
            continue  # would fall back to the local path — duplicate row
        t = _time(jax.jit(backend.fn), u, h)
        rows.append((f"kernels/conv_{name}_L{L}", t, backend.tag or name))
        # fused gate (inside the backend) vs the pre-fusion two-pass
        # schedule; the delta == one eliminated full-tensor pass per order
        fused = jax.jit(lambda u, h, s, g, b=backend: b(u, h, s, g))
        unfused = jax.jit(
            lambda u, h, s, g, b=backend: g * b(u, h, s).astype(g.dtype)
        )
        t_f = _time(fused, u, h, skip, gate)
        t_u = _time(unfused, u, h, skip, gate)
        rows.append((
            f"kernels/conv_{name}_gated_fused_L{L}", t_f,
            f"unfused_us={t_u:.0f};saved_passes_per_order=1",
        ))
        rows.append((
            f"kernels/conv_{name}_gated_unfused_L{L}", t_u,
            backend.tag or name,
        ))

    # fusion accounting for the artifact: the gated contract removes one
    # full-tensor (B, L, D) write+read per order per layer vs the
    # pre-fusion operator (gate applied as a standalone multiply).  Inside
    # ONE xla jit the compiler fuses that multiply anyway (CPU deltas above
    # hover near zero — that is the point: bit-identical, never slower);
    # the hard win is the Pallas toeplitz kernel, where pallas_call is a
    # fusion barrier and the standalone gate multiply is a real extra HBM
    # round-trip — only measurable on TPU.
    rows.append((
        "kernels/conv_gated_fusion_accounting", 0.0,
        "eliminated_full_tensor_passes_per_forward=order*n_layers;"
        "pallas_measured_on=tpu_only",
    ))

    # overlapped two-level FFT vs the staged blockfft at Hyena training
    # lengths (ISSUE 9 acceptance rows).  Narrow D keeps the CPU run cheap;
    # the schedule comparison is per-channel so the ratio transfers.  On
    # CPU blockfft_overlap degrades to the identical blockfft math — the
    # rows exist to pin the artifact shape; the overlap win itself (HBM
    # streaming hidden behind the inner-DFT matmuls inside one
    # pallas_call) is only measurable on TPU.
    from repro.core.conv_api import get_conv_backend

    bf = get_conv_backend("blockfft")
    ov = get_conv_backend("blockfft_overlap")
    for Lx in (8192, 32768):
        Bx, Dx = 1, 4
        ux = jax.random.normal(jax.random.PRNGKey(6), (Bx, Lx, Dx))
        hx = jax.random.normal(jax.random.PRNGKey(7), (Dx, Lx)) / Lx
        t_bf = _time(jax.jit(bf.fn), ux, hx, iters=2)
        t_ov = _time(jax.jit(ov.fn), ux, hx, iters=2)
        rows.append((
            f"kernels/conv_blockfft_L{Lx}", t_bf,
            f"vs_overlap_us={t_ov:.0f}",
        ))
        rows.append((
            f"kernels/conv_blockfft_overlap_L{Lx}", t_ov,
            f"vs_blockfft_us={t_bf:.0f}",
        ))
    # accounting row for the two-level overlapped schedule (CI-asserted):
    # what the single-pallas_call pipeline removes relative to the staged
    # blockfft lowering, and where the numbers are real.
    rows.append((
        "kernels/conv_twolevel_overlap_accounting", 0.0,
        "pipelined_stages=inner_fft,pointwise,outer_combine;"
        "hbm_roundtrips_staged=5;hbm_roundtrips_overlapped=1;"
        "plan_kind=twolevel;cpu=degrades_to_blockfft;"
        "measured_on=tpu_only",
    ))

    g = jax.random.normal(jax.random.PRNGKey(2), (D,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(3), (B * L, D))
    rn_t = _time(jax.jit(lambda x, g: ref.rmsnorm(x, g)), x, g)
    rows.append(("kernels/rmsnorm_ref", rn_t, "oracle"))
    return rows
