"""Kernel micro-bench (§4.4 supplement): interpret-mode correctness-path
timing of each Pallas kernel vs its jnp oracle, plus the conv-backend
comparison (fft vs blockfft vs toeplitz) that drives the §Perf iteration.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(rows):
    from repro.core.conv_api import registered_conv_backends
    from repro.kernels import ref

    B, L, D = 2, 2048, 64
    u = jax.random.normal(jax.random.PRNGKey(0), (B, L, D))
    h = jax.random.normal(jax.random.PRNGKey(1), (D, L)) / L
    # conv-backend comparison straight off the registry: new backends show
    # up here (and in the §Perf iteration) with zero bench edits.
    from repro.distributed.ctx import current_mesh

    for name, backend in sorted(registered_conv_backends().items()):
        if backend.oracle or (backend.max_len and L > backend.max_len):
            continue  # O(L²) references are not a timing row at L=2048
        if backend.requires_pallas and jax.default_backend() != "tpu":
            continue  # interpret-mode timing is meaningless
        if backend.mesh_aware and current_mesh() is None:
            continue  # would fall back to the local path — duplicate row
        t = _time(jax.jit(backend.fn), u, h)
        rows.append((f"kernels/conv_{name}_L{L}", t, backend.tag or name))

    g = jax.random.normal(jax.random.PRNGKey(2), (D,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(3), (B * L, D))
    rn_t = _time(jax.jit(lambda x, g: ref.rmsnorm(x, g)), x, g)
    rows.append(("kernels/rmsnorm_ref", rn_t, "oracle"))
    return rows
