"""Figure 4.1 / Table 4.2 reproduction (CPU-scaled): the paper's case for
*implicit long* filters over *explicit short* (Conv1d) ones, probed two
ways at container scale:

1. **Associative recall accuracy** (held-out dictionaries): a 2-layer
   width-64 Hyena (the paper's synthetics config, Table A.1) vs the same
   model with filters hard-truncated to 4 taps (the Conv1d-size-M
   baseline).  Trained at the budget this container affords.
2. **Memory extent** (paper §2.1 "Long convolutions and memory"): the
   gradient-based reach ``|∂y_t/∂u_{t-n}|`` of the trained operator — the
   deterministic mechanistic signature of unrestricted vs truncated
   context, independent of training noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import split_params
from repro.configs import get_config
from repro.data import synthetic
from repro.models import lm
from repro.train import optim as O
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def _train_eval(cfg, tokens, labels, test_tokens, test_labels,
                steps=120, lr=2e-3):
    tcfg = TrainConfig(
        optimizer=O.AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps,
                                weight_decay=0.0),
        remat=False,
    )
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    for _ in range(steps):
        state, metrics = step(state, batch)
    logits, _ = lm.forward(state["params"], cfg, jnp.asarray(test_tokens))
    acc = synthetic.eval_accuracy(np.asarray(logits, np.float32),
                                  np.asarray(test_labels))
    return acc, state["params"]


def memory_extent(params, cfg, L=32, thresh=0.01):
    """Largest n with normalized |∂y_L/∂u_{L-n}| > thresh (paper §2.1)."""
    from repro.models.blocks import mixer_config
    from repro.models.hyena import apply_hyena_mixer

    mc = mixer_config(cfg, "hyena")
    mixer_params = jax.tree_util.tree_map(
        lambda a: a[0], params["groups"][0]
    )["mixer"]
    u = jax.random.normal(jax.random.PRNGKey(3), (1, L, cfg.d_model))

    def out_last(u):
        y = apply_hyena_mixer(mixer_params, mc, u)
        return jnp.sum(jnp.abs(y[0, -1]))

    g = jax.grad(out_last)(u)[0]  # (L, D)
    reach = np.asarray(jnp.linalg.norm(g.astype(jnp.float32), axis=-1))
    reach = reach / (reach.max() + 1e-9)
    nz = np.nonzero(reach > thresh)[0]
    return int(L - 1 - nz.min()) if len(nz) else 0


def run(rows):
    base = get_config("hyena-153m").reduced()
    vocab, seq = 12, 32
    rng = np.random.default_rng(0)
    tokens, labels = synthetic.associative_recall(rng, n=256, seq_len=seq,
                                                  vocab=vocab)
    t_tokens, t_labels = synthetic.associative_recall(rng, n=128, seq_len=seq,
                                                      vocab=vocab)
    cfg_imp = dataclasses.replace(
        base, name="recall-implicit", vocab_size=16, n_layers=2, d_model=64,
    )
    cfg_exp = dataclasses.replace(
        cfg_imp, name="recall-explicit-short", hyena_max_support=4,
    )
    acc_imp, p_imp = _train_eval(cfg_imp, tokens, labels, t_tokens, t_labels)
    acc_exp, p_exp = _train_eval(cfg_exp, tokens, labels, t_tokens, t_labels)
    chance = 2.0 / vocab
    rows.append((f"fig4.1/recall_v{vocab}_implicit_long", 0.0, f"{acc_imp:.2f}"))
    rows.append((f"fig4.1/recall_v{vocab}_explicit_short", 0.0, f"{acc_exp:.2f}"))
    rows.append(("fig4.1/recall_chance", 0.0, f"{chance:.2f}"))
    # mechanistic memory reach (paper §2.1): unrestricted vs truncated
    rows.append(
        ("fig4.1/memory_extent_implicit", 0.0,
         str(memory_extent(p_imp, cfg_imp)))
    )
    rows.append(
        ("fig4.1/memory_extent_explicit_short", 0.0,
         str(memory_extent(p_exp, cfg_exp)))
    )
    return rows
