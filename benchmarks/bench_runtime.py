"""Figure 4.3 reproduction: runtime of the Hyena operator vs dense
attention as sequence length grows, locating the crossover.  CPU container:
absolute times differ from the paper's A100s, but the asymptotic crossover
(quadratic attention vs L·logL Hyena) is the claim being validated.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.common.param import split_params


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))  # compile + warm-up
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(rows):
    from repro.core import HyenaConfig, FilterConfig
    from repro.core.operator import init_hyena, hyena_operator
    from repro.models.attention import AttentionConfig, apply_attention, init_attention

    D, B = 64, 1
    hcfg = HyenaConfig(d_model=D, order=2,
                       filter=FilterConfig(d_model=D, order=2, ffn_width=32,
                                           pos_dim=17))
    hp, _ = split_params(init_hyena(jax.random.PRNGKey(0), hcfg))
    acfg = AttentionConfig(d_model=D, n_heads=4, n_kv_heads=4, head_dim=16,
                           chunk_kv=1 << 30)  # dense path
    ap, _ = split_params(init_attention(jax.random.PRNGKey(1), acfg))

    hy_f = jax.jit(lambda p, u: hyena_operator(p, hcfg, u))
    at_f = jax.jit(lambda p, u: apply_attention(p, acfg, u))

    crossover = None
    prev = None
    for L in [256, 512, 1024, 2048, 4096, 8192]:
        u = jax.random.normal(jax.random.PRNGKey(2), (B, L, D))
        t_h = _time(hy_f, hp, u)
        t_a = _time(at_f, ap, u)
        rows.append((f"fig4.3/hyena_L{L}", t_h, f"attn_us={t_a:.0f}"))
        if prev is not None and t_h < t_a and crossover is None:
            crossover = L
        prev = (t_h, t_a)
    rows.append(("fig4.3/crossover_seqlen", 0.0, str(crossover)))
    return rows
