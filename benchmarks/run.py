# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  bench_recall    — Fig 4.1 / Table 4.2 (associative recall, implicit vs
                    explicit filter parameterization, vocab scaling)
  bench_lm_flops  — Table 4.4 (GPT vs Hyena total-FLOP accounting)
  bench_runtime   — Fig 4.3 (operator runtime crossover vs attention)
  bench_kernels   — §4.4 supplement (conv backend micro-bench)
  bench_roofline  — §Roofline terms from the multi-pod dry-run artifacts

``--json PATH`` additionally writes the rows as a machine-readable artifact.
Convention: perf-trajectory artifacts are committed as ``BENCH_<topic>.json``
at the repo root (``BENCH_conv.json`` = the conv-backend/gated-fusion rows
from ``--only kernels``), so successive PRs are held to a measured baseline.
Each artifact records the jax backend and device — CI writes interpret/CPU
numbers, which are comparable only to other CI runs.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single bench module")
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write rows to PATH as a BENCH_*.json artifact",
    )
    args = ap.parse_args()

    from benchmarks import (
        bench_kernels,
        bench_lm_flops,
        bench_recall,
        bench_roofline,
        bench_runtime,
    )

    modules = {
        "recall": bench_recall,
        "lm_flops": bench_lm_flops,
        "runtime": bench_runtime,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    rows = []
    errors = []
    spans = {}  # module -> (start, end) row indices, for the summary block
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        try:
            start = len(rows)
            mod.run(rows)
            spans[name] = (start, len(rows))
            for r in rows[start:]:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
                sys.stdout.flush()
        except Exception:
            err = traceback.format_exc(limit=1)
            errors.append({"module": name, "error": err})
            print(f"{name}/ERROR,0.0,{err!r}")

    if args.json:
        import jax

        # schema 2: one scalar headline metric per suite so perf-trajectory
        # tooling can plot the history without knowing each suite's row
        # vocabulary.  A module nominates its headline row via HEADLINE;
        # otherwise its first row stands in.
        summary = {}
        for name, mod in modules.items():
            start, end = spans.get(name, (0, 0))
            mod_rows = rows[start:end]
            if not mod_rows:
                continue
            headline = getattr(mod, "HEADLINE", None)
            pick = next(
                (r for r in mod_rows if r[0] == headline), mod_rows[0]
            )
            summary[name] = {
                "metric": pick[0],
                "value": round(float(pick[1]), 1),
                "unit": "us_per_call",
            }
        artifact = {
            "schema": 2,
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]).split(":")[0],
            "modules": sorted(modules),
            "summary": summary,
            "rows": [
                {"name": n, "us_per_call": round(t, 1), "derived": str(d)}
                for n, t, d in rows
            ],
            "errors": errors,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json} ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
