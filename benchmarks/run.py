# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  bench_recall    — Fig 4.1 / Table 4.2 (associative recall, implicit vs
                    explicit filter parameterization, vocab scaling)
  bench_lm_flops  — Table 4.4 (GPT vs Hyena total-FLOP accounting)
  bench_runtime   — Fig 4.3 (operator runtime crossover vs attention)
  bench_kernels   — §4.4 supplement (conv backend micro-bench)
  bench_roofline  — §Roofline terms from the multi-pod dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single bench module")
    args = ap.parse_args()

    from benchmarks import (
        bench_kernels,
        bench_lm_flops,
        bench_recall,
        bench_roofline,
        bench_runtime,
    )

    modules = {
        "recall": bench_recall,
        "lm_flops": bench_lm_flops,
        "runtime": bench_runtime,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    rows = []
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        try:
            start = len(rows)
            mod.run(rows)
            for r in rows[start:]:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
                sys.stdout.flush()
        except Exception:
            print(f"{name}/ERROR,0.0,{traceback.format_exc(limit=1)!r}")


if __name__ == "__main__":
    main()
