"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.

    PYTHONPATH=src:. python -m benchmarks.report [--base artifacts/dryrun_baseline]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import load_cells, terms

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, digits=2):
    return f"{x:.{digits}e}" if isinstance(x, float) else str(x)


def roofline_table(art_dir: str) -> str:
    cells = load_cells(art_dir)
    cells.sort(key=lambda c: (SHAPE_ORDER.index(c["shape"]), c["arch"]))
    lines = [
        "| arch | shape | note | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS | useful ratio | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        t = terms(c)
        if t is None:
            continue
        note = "hyena-swap" if c.get("hyena_swap") else ""
        lines.append(
            f"| {c['arch']} | {c['shape']} | {note} "
            f"| {t['t_compute_s']:.2e} | {t['t_memory_s']:.2e} "
            f"| {t['t_collective_s']:.2e} | **{t['dominant']}** "
            f"| {c.get('model_flops', 0):.2e} "
            f"| {t['useful_flops_ratio'] if t['useful_flops_ratio'] is not None else 0:.2f} "
            f"| {t['mfu_bound']:.3f} |"
        )
    return "\n".join(lines)


def dryrun_table(art_root: str) -> str:
    lines = [
        "| mesh | arch | shape | status | compile s | temp bytes/dev | args bytes/dev "
        "| flops/dev (extrap) | collective bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for mesh in ["pod16x16", "pod2x16x16"]:
        for f in sorted(glob.glob(os.path.join(art_root, mesh, "*.json"))):
            c = json.load(open(f))
            if c.get("status") != "ok":
                lines.append(f"| {mesh} | {c['arch']} | {c['shape']} | FAILED | | | | | |")
                continue
            mem = c["full"]["memory"]
            src = c.get("extrapolated") or {}
            fl = src.get("flops") or c["full"]["cost_analysis"].get("flops") or 0
            coll = sum((src.get("collectives") or c["full"].get("collectives", {})).values())
            lines.append(
                f"| {mesh} | {c['arch']} | {c['shape']} | ok "
                f"| {c['full']['compile_s']:.0f} "
                f"| {(mem['temp_bytes'] or 0)/1e9:.2f}G "
                f"| {(mem['argument_bytes'] or 0)/1e9:.2f}G "
                f"| {fl:.2e} | {coll/1e9:.1f}G |"
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    doc = []
    doc.append("### Roofline (single-pod 16×16, optimized)\n")
    doc.append(roofline_table(os.path.join(args.art, "pod16x16")))
    base = "artifacts/dryrun_baseline/pod16x16"
    if os.path.isdir(base):
        doc.append("\n\n### Roofline (single-pod 16×16, paper-faithful baseline)\n")
        doc.append(roofline_table(base))
    doc.append("\n\n### Dry-run compile record (both meshes)\n")
    doc.append(dryrun_table(args.art))
    text = "\n".join(doc)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
