"""Serving throughput: continuous batching vs the padded static batch.

Workload: ``--requests`` requests with mixed prompt lengths and decode
horizons, arriving as a Poisson process (``--rate`` per decode step).

  * **static** — the pre-PR baseline: requests are grouped FIFO into
    batches of ``--slots``, every batch left-padded to its longest prompt
    and decoded for its *longest* horizon (``generate()``); short requests
    burn the whole batch on their slowest member.
  * **continuous** — ``ServeEngine``: a finished or stopped request frees
    its slot immediately and the next arrival is prefilled into it, so no
    decode step is spent on a request that is already done.

Tokens/sec counts *useful* tokens only (each request's own horizon).  Both
paths run once for compilation and are timed on the second run.

    PYTHONPATH=src python benchmarks/bench_serving.py --arch hyena-153m
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import split_params
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine, generate

PROMPT_LENS = (6, 8, 12, 16)
# long-tailed horizons: most requests are short, a few are very long —
# the padded static batch decodes EVERY request to its batch's longest
# horizon, so the expected per-batch waste grows with the slot count
HORIZONS = (2, 3, 4, 6, 8, 12, 16, 24, 48, 96)


def make_workload(n_requests: int, rate: float, vocab: int, seed: int):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.poisson(1.0 / max(rate, 1e-6), n_requests))
    return [
        {
            "arrival": int(arrivals[i]),
            "prompt": rng.integers(0, vocab, rng.choice(PROMPT_LENS)).astype(
                np.int32
            ),
            "horizon": int(rng.choice(HORIZONS)),
        }
        for i in range(n_requests)
    ]


@functools.partial(
    jax.jit, static_argnames=("cfg", "scfg", "max_new")
)
def _static_generate(params, prompts, *, cfg, scfg, max_new):
    # jitted wrapper so the static baseline pays zero per-call retracing —
    # the comparison is scheduling policy, not dispatch overhead
    return generate(params, cfg, prompts, scfg=scfg, max_new_tokens=max_new)


def run_static(params, cfg, scfg, workload, slots):
    """FIFO batches of `slots`, padded to batch-max prompt + horizon."""
    done_tokens = 0
    for i in range(0, len(workload), slots):
        batch = workload[i : i + slots]
        width = max(len(r["prompt"]) for r in batch)
        horizon = max(r["horizon"] for r in batch)
        padded = np.stack([
            np.pad(r["prompt"], (width - len(r["prompt"]), 0)) for r in batch
        ])
        out = _static_generate(params, jnp.asarray(padded), cfg=cfg,
                               scfg=scfg, max_new=horizon)
        jax.block_until_ready(out)
        done_tokens += sum(r["horizon"] for r in batch)  # useful only
    return done_tokens


def run_continuous(params, cfg, scfg, workload, quantum):
    eng = ServeEngine(
        params, cfg, dataclasses.replace(scfg, decode_quantum=quantum)
    )
    pending = sorted(workload, key=lambda r: r["arrival"])
    t, done_tokens = 0, 0
    while pending or not eng.scheduler.idle:
        while pending and pending[0]["arrival"] <= t:
            r = pending.pop(0)
            eng.submit(r["prompt"], max_new_tokens=r["horizon"])
            done_tokens += r["horizon"]
        eng.step()
        t += 1
    return done_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hyena-153m")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--quantum", type=int, default=1,
                    help="decode steps fused per continuous scheduler tick; "
                    ">1 amortizes host dispatch (wins when the model is so "
                    "small that dispatch dominates) at the cost of surplus "
                    "decode past stop conditions — at bench sizes compute "
                    "dominates, so 1 is optimal")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean arrivals per decode step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=512,
                    help="widen the reduced config so compute dominates "
                    "the per-step dispatch overhead")
    ap.add_argument("--layers", type=int, default=6,
                    help="deepen the reduced config (same reason)")
    args = ap.parse_args()

    base = get_config(args.arch).reduced()
    plen = len(base.pattern)
    cfg = dataclasses.replace(
        base,
        frontend=None, frontend_len=0,
        d_model=args.d_model, vocab_size=512,
        n_layers=max(args.layers - args.layers % plen, plen),
    )
    params, _ = split_params(lm.init_lm(jax.random.PRNGKey(0), cfg))
    max_len = max(PROMPT_LENS) + max(HORIZONS) + 1
    scfg = ServeConfig(max_len=max_len, temperature=0.0,
                       n_slots=args.slots, cache_dtype=jnp.bfloat16)
    workload = make_workload(args.requests, args.rate, cfg.vocab_size,
                             args.seed)
    useful = sum(r["horizon"] for r in workload)
    print(f"arch={cfg.name} d_model={cfg.d_model} requests={args.requests} "
          f"slots={args.slots} useful_tokens={useful}")

    rows = []
    for name, fn in [
        ("static", lambda: run_static(params, cfg, scfg, workload,
                                      args.slots)),
        ("continuous", lambda: run_continuous(params, cfg, scfg, workload,
                                              args.quantum)),
    ]:
        fn()  # warm-up: compile every (shape, horizon) cell
        t0 = time.perf_counter()
        toks = fn()
        dt = time.perf_counter() - t0
        rows.append((name, toks, dt, toks / dt))
        print(f"  {name:<12} {toks:5d} tokens  {dt:7.2f}s  "
              f"{toks / dt:8.1f} tok/s")

    ratio = rows[1][3] / rows[0][3]
    print(f"continuous / static throughput: {ratio:.2f}x "
          f"({'PASS' if ratio >= 2.0 else 'below'} the 2x acceptance bar)")


if __name__ == "__main__":
    main()
