"""§Roofline table emission: three terms + dominant bottleneck per
(arch × shape) cell, from the dry-run artifacts (Table/§ of EXPERIMENTS.md).
"""
from __future__ import annotations

from benchmarks.roofline import load_cells, terms


def run(rows):
    cells = load_cells()
    if not cells:
        rows.append(("roofline/no_artifacts_yet", 0.0, "run launch.dryrun"))
        return rows
    for c in cells:
        t = terms(c)
        if t is None:
            continue
        name = f"roofline/{c['arch']}__{c['shape']}"
        derived = (
            f"c={t['t_compute_s']:.2e};m={t['t_memory_s']:.2e};"
            f"n={t['t_collective_s']:.2e};dom={t['dominant']};"
            f"mfu_bound={t['mfu_bound']:.3f}"
        )
        rows.append((name, t["ideal_step_s"] * 1e6, derived))
    return rows
